//! Composed operators: quantizer ∘ sparsifier (paper §2.3).
//!
//! * `QTopK` — QSGD applied to the Top_k (or Rand_k) subvector. Unscaled
//!   (Lemma 1, requires β_{k,s} < 1 for the γ guarantee) or scaled by
//!   1/(1+β_{k,s}) (Lemma 2, always a compression operator).
//! * `SignTopK` — deterministic sign of the Top_k subvector scaled by
//!   ‖Top_k(x)‖_m / k (Lemma 3).

use super::quantize::Qsgd;
use super::sparsify::top_k_indices_into;
use super::{Compressor, Message, MessageBuf};
use crate::util::rng::Pcg64;
use crate::util::stats::{norm1, norm2};

/// QSGD ∘ {Top_k | Rand_k}.
#[derive(Clone, Debug)]
pub struct QTopK {
    pub k: usize,
    pub q: Qsgd,
    /// Apply the 1/(1+β_{k,s}) post-scale of Lemma 2.
    pub scaled: bool,
    /// Use Rand_k instead of Top_k as the sparsifier.
    pub rand: bool,
}

impl QTopK {
    pub fn new(k: usize, q: Qsgd, scaled: bool) -> Self {
        assert!(k > 0);
        QTopK { k, q, scaled, rand: false }
    }

    pub fn new_rand(k: usize, q: Qsgd, scaled: bool) -> Self {
        assert!(k > 0);
        QTopK { k, q, scaled, rand: true }
    }

    /// β_{k,s}: the quantizer's blow-up evaluated at the *sparsified*
    /// dimension k (Lemma 1 treats Comp_k(x) as a length-k vector).
    pub fn beta_k(&self) -> f64 {
        self.q.beta(self.k)
    }
}

impl Compressor for QTopK {
    fn compress(&self, x: &[f32], rng: &mut Pcg64) -> Message {
        super::compress_owned(self, x, rng)
    }

    fn compress_into(&self, x: &[f32], rng: &mut Pcg64, buf: &mut MessageBuf) {
        let (mut norms, mut idx, mut levels, mut neg) = buf.take_qsgd();
        let d = x.len();
        let k = self.k.min(d);
        if self.rand {
            idx.extend(rng.sample_indices(d, k).into_iter().map(|i| i as u32));
            idx.sort_unstable();
        } else {
            top_k_indices_into(x, k, &mut idx, &mut buf.topk);
        }
        buf.vals.clear();
        buf.vals.extend(idx.iter().map(|&i| x[i as usize]));
        self.q.quantize_values_into(&buf.vals, rng, &mut norms, &mut levels, &mut neg);
        let post_scale = if self.scaled {
            (1.0 / (1.0 + self.beta_k())) as f32
        } else {
            1.0
        };
        buf.msg = Message::Qsgd {
            d,
            s: self.q.s,
            bucket: self.q.bucket as u32,
            norms,
            post_scale,
            idx: Some(idx),
            levels,
            neg,
        };
    }

    fn gamma(&self, d: usize) -> f64 {
        let k = self.k.min(d) as f64;
        let d = d.max(1) as f64;
        let beta = self.beta_k();
        if self.scaled {
            // Lemma 2: γ = k / (d(1+β)) — valid for all β.
            k / (d * (1.0 + beta))
        } else {
            // Lemma 1: γ = (1 − β) k/d — requires β < 1.
            ((1.0 - beta) * k / d).max(0.0)
        }
    }

    fn name(&self) -> String {
        let levels = self.q.level_label();
        let sp = if self.rand { "randk" } else { "topk" };
        if self.scaled {
            format!("q{sp}_scaled(k={},{levels})", self.k)
        } else {
            format!("q{sp}(k={},{levels})", self.k)
        }
    }
}

/// Sign ∘ Top_k with m-norm scaling (Lemma 3):
/// C(x) = (‖Top_k(x)‖_m / k) · SignTop_k(x).
#[derive(Clone, Debug)]
pub struct SignTopK {
    pub k: usize,
    /// Norm index m ∈ {1, 2}; the paper's experiments use m = 1.
    pub m: u32,
}

impl SignTopK {
    pub fn new(k: usize, m: u32) -> Self {
        assert!(k > 0);
        assert!(m >= 1, "m must be a positive integer");
        SignTopK { k, m }
    }
}

impl Compressor for SignTopK {
    fn compress(&self, x: &[f32], rng: &mut Pcg64) -> Message {
        super::compress_owned(self, x, rng)
    }

    fn compress_into(&self, x: &[f32], _rng: &mut Pcg64, buf: &mut MessageBuf) {
        let (mut idx, mut neg) = buf.take_sparse_sign();
        let d = x.len();
        let k = self.k.min(d);
        top_k_indices_into(x, k, &mut idx, &mut buf.topk);
        // Gather the selected values into scratch so the m-norm goes through
        // the same helpers (same accumulation order) as the allocating path.
        buf.vals.clear();
        buf.vals.extend(idx.iter().map(|&i| x[i as usize]));
        let nm = match self.m {
            1 => norm1(&buf.vals),
            2 => norm2(&buf.vals),
            m => buf
                .vals
                .iter()
                .map(|v| (v.abs() as f64).powi(m as i32))
                .sum::<f64>()
                .powf(1.0 / m as f64),
        };
        let scale = (nm / k as f64) as f32;
        neg.extend(buf.vals.iter().map(|&v| v < 0.0));
        buf.msg = Message::SparseSign { d, scale, idx, neg };
    }

    fn gamma(&self, d: usize) -> f64 {
        let k = self.k.min(d) as f64;
        let d = d.max(1) as f64;
        match self.m {
            // Lemma 3, m = 1: γ ≥ 1/d (the max's first term; the second term
            // is data-dependent).
            1 => 1.0 / d,
            // m ≥ 2: γ = k^{2/m − 1} / d.
            m => k.powf(2.0 / m as f64 - 1.0) / d,
        }
    }

    fn name(&self) -> String {
        format!("signtopk(k={},m={})", self.k, self.m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::norm2_sq;

    #[test]
    fn qtopk_support_is_topk() {
        let x = vec![0.1f32, -5.0, 0.2, 3.0, -0.05, 4.0];
        let mut rng = Pcg64::seeded(20);
        let op = QTopK::new(3, Qsgd::from_bits(8), false);
        match op.compress(&x, &mut rng) {
            Message::Qsgd { idx: Some(idx), .. } => assert_eq!(idx, vec![1, 3, 5]),
            _ => panic!("wrong message"),
        }
    }

    #[test]
    fn qtopk_fine_quantizer_close_to_topk() {
        // With many levels, QTop_k(x) ≈ Top_k(x).
        let mut rng = Pcg64::seeded(21);
        let x: Vec<f32> = (0..256).map(|_| rng.normal_f32()).collect();
        let op = QTopK::new(32, Qsgd::from_bits(12), false);
        let dense = op.compress(&x, &mut rng).to_dense();
        let topk = super::super::sparsify::TopK::new(32)
            .compress(&x, &mut rng)
            .to_dense();
        let diff: Vec<f32> = dense.iter().zip(&topk).map(|(a, b)| a - b).collect();
        assert!(norm2_sq(&diff) < 1e-4 * norm2_sq(&topk));
    }

    #[test]
    fn qtopk_compression_property_empirical() {
        // E‖x − C(x)‖² ≤ (1 − γ)‖x‖² with γ from Lemma 1 / Lemma 2.
        let mut rng = Pcg64::seeded(22);
        let d = 128;
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        for scaled in [false, true] {
            let op = QTopK::new(16, Qsgd::from_bits(4), scaled); // β_{16,15} < 1
            let gamma = op.gamma(d);
            assert!(gamma > 0.0);
            let trials = 4000;
            let mut acc = 0.0;
            for _ in 0..trials {
                let dense = op.compress(&x, &mut rng).to_dense();
                let resid: Vec<f32> = x.iter().zip(&dense).map(|(a, b)| a - b).collect();
                acc += norm2_sq(&resid);
            }
            let mean = acc / trials as f64;
            let bound = (1.0 - gamma) * norm2_sq(&x);
            assert!(mean <= bound * 1.03, "scaled={scaled}: {mean} > {bound}");
        }
    }

    #[test]
    fn scaled_gamma_beats_unscaled_when_beta_lt_1() {
        // Remark 2: (1−β)k/d < k/(d(1+β)) whenever 0 < β < 1.
        let unscaled = QTopK::new(16, Qsgd::from_bits(4), false);
        let scaled = QTopK::new(16, Qsgd::from_bits(4), true);
        assert!(unscaled.beta_k() < 1.0);
        assert!(scaled.gamma(1024) > unscaled.gamma(1024));
    }

    #[test]
    fn signtopk_value_and_compression() {
        let x = vec![4.0f32, -2.0, 1.0, 0.5];
        let mut rng = Pcg64::seeded(23);
        let op = SignTopK::new(2, 1);
        let m = op.compress(&x, &mut rng);
        // Top_2 = {4, -2}; ‖·‖₁ = 6; scale = 3.
        match &m {
            Message::SparseSign { scale, idx, neg, .. } => {
                assert_eq!(idx, &vec![0, 1]);
                assert_eq!(neg, &vec![false, true]);
                assert!((scale - 3.0).abs() < 1e-6);
            }
            _ => panic!("wrong message"),
        }
        // Deterministic compression property with γ from Lemma 3 (m=1 uses the
        // data-dependent second term; here the max evaluates to
        // (k/d)(‖v‖₁/(√k‖v‖₂))² = (2/4)·(6/(√2·√20))² = 0.45).
        let dense = m.to_dense();
        let resid: Vec<f32> = x.iter().zip(&dense).map(|(a, b)| a - b).collect();
        let v_n1: f64 = 6.0;
        let v_n2_sq: f64 = 20.0;
        let gamma_data = (2.0 / 4.0) * v_n1 * v_n1 / (2.0 * v_n2_sq);
        assert!(norm2_sq(&resid) <= (1.0 - gamma_data) * norm2_sq(&x) + 1e-6);
    }

    #[test]
    fn signtopk_m2_gamma() {
        let op = SignTopK::new(16, 2);
        assert!((op.gamma(256) - 1.0 / 256.0).abs() < 1e-12); // k^0/d
    }
}
