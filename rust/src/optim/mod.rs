//! Local optimizers and learning-rate schedules.
//!
//! The paper's experiments run SGD with momentum 0.9 *on the local
//! iterations* (§5.1.1) for the non-convex case, and plain SGD with an
//! inverse-time decaying rate c/(λ(a+t)) for the convex case (§5.2.2).

/// Learning-rate schedule η_t.
#[derive(Clone, Debug, PartialEq)]
pub enum LrSchedule {
    /// η_t = η (Theorems 1, 4).
    Const { eta: f64 },
    /// η_t = ξ / (a + t) (Theorems 2, 3, 5, 6 and the convex experiments,
    /// where ξ = c/λ and a = dH/k per §5.2.2).
    InvTime { xi: f64, a: f64 },
    /// Linear warmup for `warmup` steps to `peak`, then multiply by `decay`
    /// at each milestone (the ResNet-50 schedule of §5.1.1).
    WarmupPiecewise { peak: f64, warmup: usize, milestones: Vec<usize>, decay: f64 },
}

impl LrSchedule {
    pub fn at(&self, t: usize) -> f64 {
        match self {
            LrSchedule::Const { eta } => *eta,
            LrSchedule::InvTime { xi, a } => xi / (a + t as f64),
            LrSchedule::WarmupPiecewise { peak, warmup, milestones, decay } => {
                if t < *warmup {
                    peak * (t + 1) as f64 / *warmup as f64
                } else {
                    let drops = milestones.iter().filter(|&&m| t >= m).count() as i32;
                    peak * decay.powi(drops)
                }
            }
        }
    }
}

/// Local optimizer state (per worker). Momentum is applied to the local
/// steps, exactly as in the paper's experiments; the *transmitted* quantity
/// is always the net parameter displacement, so the coordinator is agnostic
/// to the local optimizer.
#[derive(Clone, Debug)]
pub struct LocalSgd {
    pub momentum: f64,
    pub weight_decay: f64,
    velocity: Vec<f32>,
}

impl LocalSgd {
    pub fn new(d: usize, momentum: f64, weight_decay: f64) -> Self {
        LocalSgd { momentum, weight_decay, velocity: vec![0.0; d] }
    }

    pub fn plain(d: usize) -> Self {
        Self::new(d, 0.0, 0.0)
    }

    /// One local step: x ← x − η (v) with v = μ·v + g + wd·x.
    pub fn step(&mut self, x: &mut [f32], grad: &[f32], eta: f64) {
        debug_assert_eq!(x.len(), grad.len());
        debug_assert_eq!(x.len(), self.velocity.len());
        let mu = self.momentum as f32;
        let wd = self.weight_decay as f32;
        let eta = eta as f32;
        if mu == 0.0 && wd == 0.0 {
            for (xi, gi) in x.iter_mut().zip(grad) {
                *xi -= eta * gi;
            }
            return;
        }
        for ((xi, gi), vi) in x.iter_mut().zip(grad).zip(self.velocity.iter_mut()) {
            let g = gi + wd * *xi;
            *vi = mu * *vi + g;
            *xi -= eta * *vi;
        }
    }

    /// Reset momentum (used when local state is replaced by the global model
    /// in variants that drop local velocity at sync; default keeps it).
    pub fn reset(&mut self) {
        self.velocity.iter_mut().for_each(|v| *v = 0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn const_and_invtime() {
        let c = LrSchedule::Const { eta: 0.1 };
        assert_eq!(c.at(0), 0.1);
        assert_eq!(c.at(1000), 0.1);
        let it = LrSchedule::InvTime { xi: 8.0, a: 2.0 };
        assert!((it.at(0) - 4.0).abs() < 1e-12);
        assert!((it.at(6) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn warmup_piecewise() {
        let s = LrSchedule::WarmupPiecewise {
            peak: 1.0,
            warmup: 10,
            milestones: vec![30, 60],
            decay: 0.1,
        };
        assert!((s.at(0) - 0.1).abs() < 1e-12);
        assert!((s.at(9) - 1.0).abs() < 1e-12);
        assert!((s.at(20) - 1.0).abs() < 1e-12);
        assert!((s.at(30) - 0.1).abs() < 1e-12);
        assert!((s.at(60) - 0.01).abs() < 1e-12);
    }

    #[test]
    fn plain_sgd_step() {
        let mut opt = LocalSgd::plain(3);
        let mut x = vec![1.0f32, 2.0, 3.0];
        opt.step(&mut x, &[1.0, 0.0, -1.0], 0.5);
        assert_eq!(x, vec![0.5, 2.0, 3.5]);
    }

    #[test]
    fn momentum_accumulates() {
        let mut opt = LocalSgd::new(1, 0.9, 0.0);
        let mut x = vec![0.0f32];
        opt.step(&mut x, &[1.0], 1.0); // v=1, x=-1
        opt.step(&mut x, &[1.0], 1.0); // v=1.9, x=-2.9
        assert!((x[0] + 2.9).abs() < 1e-6);
    }

    #[test]
    fn weight_decay_pulls_to_zero() {
        let mut opt = LocalSgd::new(1, 0.0, 0.1);
        let mut x = vec![10.0f32];
        opt.step(&mut x, &[0.0], 1.0);
        assert!((x[0] - 9.0).abs() < 1e-6);
    }
}
