//! Local optimizers, learning-rate schedules, and server-side optimizers.
//!
//! The paper's experiments run SGD with momentum 0.9 *on the local
//! iterations* (§5.1.1) for the non-convex case, and plain SGD with an
//! inverse-time decaying rate c/(λ(a+t)) for the convex case (§5.2.2).
//!
//! On top of the paper's plain averaging, [`ServerOpt`] adds the FedOpt
//! family of *server* optimizers (Reddi et al., *Adaptive Federated
//! Optimization*): the master treats each round's aggregated worker
//! progress Δ_t = s·Σ_r g_t^{(r)} as a pseudo-gradient and applies a
//! momentum or Adam step to the global model instead of subtracting Δ_t
//! directly. [`ServerOptSpec::Avg`] short-circuits to the paper's exact
//! incremental fold, so existing trajectories stay bit-identical.
// `unsafe` lives only in the fork-join core (`engine::parallel`,
// `coordinator::master`) — everywhere else it is a compile error.
#![forbid(unsafe_code)]

/// Learning-rate schedule η_t.
#[derive(Clone, Debug, PartialEq)]
pub enum LrSchedule {
    /// η_t = η (Theorems 1, 4).
    Const { eta: f64 },
    /// η_t = ξ / (a + t) (Theorems 2, 3, 5, 6 and the convex experiments,
    /// where ξ = c/λ and a = dH/k per §5.2.2).
    InvTime { xi: f64, a: f64 },
    /// Linear warmup for `warmup` steps to `peak`, then multiply by `decay`
    /// at each milestone (the ResNet-50 schedule of §5.1.1).
    WarmupPiecewise { peak: f64, warmup: usize, milestones: Vec<usize>, decay: f64 },
}

impl LrSchedule {
    pub fn at(&self, t: usize) -> f64 {
        match self {
            LrSchedule::Const { eta } => *eta,
            LrSchedule::InvTime { xi, a } => xi / (a + t as f64),
            LrSchedule::WarmupPiecewise { peak, warmup, milestones, decay } => {
                if t < *warmup {
                    peak * (t + 1) as f64 / *warmup as f64
                } else {
                    let drops = milestones.iter().filter(|&&m| t >= m).count() as i32;
                    peak * decay.powi(drops)
                }
            }
        }
    }
}

/// Local optimizer state (per worker). Momentum is applied to the local
/// steps, exactly as in the paper's experiments; the *transmitted* quantity
/// is always the net parameter displacement, so the coordinator is agnostic
/// to the local optimizer.
#[derive(Clone, Debug)]
pub struct LocalSgd {
    pub momentum: f64,
    pub weight_decay: f64,
    velocity: Vec<f32>,
}

impl LocalSgd {
    pub fn new(d: usize, momentum: f64, weight_decay: f64) -> Self {
        LocalSgd { momentum, weight_decay, velocity: vec![0.0; d] }
    }

    pub fn plain(d: usize) -> Self {
        Self::new(d, 0.0, 0.0)
    }

    /// One local step: x ← x − η (v) with v = μ·v + g + wd·x.
    pub fn step(&mut self, x: &mut [f32], grad: &[f32], eta: f64) {
        debug_assert_eq!(x.len(), grad.len());
        debug_assert_eq!(x.len(), self.velocity.len());
        let mu = self.momentum as f32;
        let wd = self.weight_decay as f32;
        let eta = eta as f32;
        if mu == 0.0 && wd == 0.0 {
            for (xi, gi) in x.iter_mut().zip(grad) {
                *xi -= eta * gi;
            }
            return;
        }
        for ((xi, gi), vi) in x.iter_mut().zip(grad).zip(self.velocity.iter_mut()) {
            let g = gi + wd * *xi;
            *vi = mu * *vi + g;
            *xi -= eta * *vi;
        }
    }

    /// Reset momentum (used when local state is replaced by the global model
    /// in variants that drop local velocity at sync; default keeps it).
    pub fn reset(&mut self) {
        self.velocity.iter_mut().for_each(|v| *v = 0.0);
    }

    /// The momentum buffer (checkpointing).
    pub fn velocity(&self) -> &[f32] {
        &self.velocity
    }

    /// Restore the momentum buffer from a checkpoint. The caller validates
    /// the length first (`protocol::checkpoint` rejects mismatches as a
    /// structured error before getting here).
    pub fn load_velocity(&mut self, src: &[f32]) {
        assert_eq!(src.len(), self.velocity.len(), "velocity dimension mismatch");
        self.velocity.copy_from_slice(src);
    }
}

/// Server optimizer selection — plain data, JSON/CLI round-trippable.
///
/// Grammar (`parse` / `spec_str`):
///   `avg`                                     the paper's plain averaging
///   `momentum:beta=B[,lr=L]`  (or `momentum:B`)   heavy-ball on Δ_t;
///       `lr` defaults to `1 − beta`, which keeps the steady-state step
///       magnitude equal to plain averaging (an EMA of round deltas)
///   `adam[:b1=B1,b2=B2,eps=E,lr=L]`           FedAdam-style adaptive step;
///       defaults b1=0.9, b2=0.99, eps=1e-8, lr=0.01
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub enum ServerOptSpec {
    /// `x ← x − Δ_t` folded incrementally per update — the paper's exact
    /// aggregation arithmetic (bit-identical to the historical path).
    #[default]
    Avg,
    /// `v ← β·v + Δ_t; x ← x − lr·v` (FedAvgM / server heavy-ball).
    Momentum { beta: f64, lr: f64 },
    /// `m ← b1·m + (1−b1)·Δ_t; v ← b2·v + (1−b2)·Δ_t²;
    ///  x ← x − lr·m̂ / (√v̂ + eps)` with bias-corrected m̂, v̂ (FedAdam).
    Adam { b1: f64, b2: f64, eps: f64, lr: f64 },
}

impl ServerOptSpec {
    /// Parse the CLI/JSON grammar documented on the type.
    pub fn parse(spec: &str) -> anyhow::Result<Self> {
        let (head, rest) = spec.split_once(':').map_or((spec, ""), |(h, r)| (h, r));
        // BTreeMap: `optim` is a deterministic-path module (repo-lint bans
        // RandomState-backed maps), and `kv.keys().find(..)` below reports
        // the *same* unknown key on every run only under a sorted map.
        let mut kv = std::collections::BTreeMap::new();
        let mut bare: Option<&str> = None;
        for part in rest.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            match part.split_once('=') {
                Some((k, v)) => {
                    kv.insert(k.trim(), v.trim());
                }
                None => {
                    anyhow::ensure!(
                        bare.is_none(),
                        "server-opt `{head}`: more than one bare value in `{rest}`"
                    );
                    bare = Some(part);
                }
            }
        }
        let allowed: &[&str] = match head {
            "momentum" | "mom" => &["beta", "lr"],
            "adam" => &["b1", "b2", "eps", "lr"],
            _ => &[],
        };
        if let Some(unknown) = kv.keys().find(|k| !allowed.contains(*k)) {
            anyhow::bail!(
                "server-opt `{head}`: unknown parameter `{unknown}` (allowed: {})",
                allowed.join(", ")
            );
        }
        let get = |key: &str, default: f64| -> anyhow::Result<f64> {
            match kv.get(key) {
                None => Ok(default),
                Some(v) => v
                    .parse::<f64>()
                    .map_err(|e| anyhow::anyhow!("server-opt `{head}`: bad `{key}`: {e}")),
            }
        };
        let out = match head {
            "avg" | "none" => {
                anyhow::ensure!(
                    rest.is_empty(),
                    "server-opt `avg` takes no arguments (got `{rest}`)"
                );
                ServerOptSpec::Avg
            }
            "momentum" | "mom" => {
                anyhow::ensure!(
                    bare.is_none() || !kv.contains_key("beta"),
                    "server-opt `momentum`: both a bare value and `beta=` given"
                );
                let beta = match bare {
                    Some(v) => v
                        .parse::<f64>()
                        .map_err(|e| anyhow::anyhow!("server-opt `momentum`: bad beta: {e}"))?,
                    None => get("beta", f64::NAN)?,
                };
                anyhow::ensure!(
                    beta.is_finite(),
                    "server-opt `momentum` requires `beta=` (e.g. momentum:beta=0.9)"
                );
                let lr = get("lr", 1.0 - beta)?;
                ServerOptSpec::Momentum { beta, lr }
            }
            "adam" => {
                anyhow::ensure!(bare.is_none(), "server-opt `adam` takes only key=value args");
                ServerOptSpec::Adam {
                    b1: get("b1", 0.9)?,
                    b2: get("b2", 0.99)?,
                    eps: get("eps", 1e-8)?,
                    lr: get("lr", 0.01)?,
                }
            }
            other => anyhow::bail!(
                "unknown server-opt `{other}` (expected avg | momentum:beta=B[,lr=L] | \
                 adam[:b1=..,b2=..,eps=..,lr=..])"
            ),
        };
        out.validate()?;
        Ok(out)
    }

    /// Range-check the parameters (shared by `parse` and spec validation).
    pub fn validate(&self) -> anyhow::Result<()> {
        match *self {
            ServerOptSpec::Avg => Ok(()),
            ServerOptSpec::Momentum { beta, lr } => {
                anyhow::ensure!(
                    (0.0..1.0).contains(&beta),
                    "server-opt momentum beta must be in [0, 1), got {beta}"
                );
                anyhow::ensure!(lr > 0.0 && lr.is_finite(), "server-opt momentum lr must be > 0");
                Ok(())
            }
            ServerOptSpec::Adam { b1, b2, eps, lr } => {
                anyhow::ensure!(
                    (0.0..1.0).contains(&b1) && (0.0..1.0).contains(&b2),
                    "server-opt adam b1/b2 must be in [0, 1), got b1={b1} b2={b2}"
                );
                anyhow::ensure!(eps > 0.0 && eps.is_finite(), "server-opt adam eps must be > 0");
                anyhow::ensure!(lr > 0.0 && lr.is_finite(), "server-opt adam lr must be > 0");
                Ok(())
            }
        }
    }

    /// Canonical spec string — `parse(spec_str(s)) == s` (f64 `Display`
    /// round-trips exactly).
    pub fn spec_str(&self) -> String {
        match *self {
            ServerOptSpec::Avg => "avg".to_string(),
            ServerOptSpec::Momentum { beta, lr } => format!("momentum:beta={beta},lr={lr}"),
            ServerOptSpec::Adam { b1, b2, eps, lr } => {
                format!("adam:b1={b1},b2={b2},eps={eps},lr={lr}")
            }
        }
    }

    /// Short human-readable name for legends/summaries.
    pub fn name(&self) -> String {
        match *self {
            ServerOptSpec::Avg => "avg".to_string(),
            ServerOptSpec::Momentum { beta, lr } => format!("mom(β={beta},lr={lr})"),
            ServerOptSpec::Adam { lr, .. } => format!("adam(lr={lr})"),
        }
    }

    /// True for the plain-averaging (no-op) server optimizer.
    pub fn is_avg(&self) -> bool {
        matches!(self, ServerOptSpec::Avg)
    }

    /// Build the stateful optimizer for a d-dimensional model. `None` for
    /// `Avg`: callers keep the exact incremental fold instead.
    pub fn build(&self, d: usize) -> Option<Box<dyn ServerOpt>> {
        match *self {
            ServerOptSpec::Avg => None,
            ServerOptSpec::Momentum { beta, lr } => Some(Box::new(ServerMomentum {
                beta: beta as f32,
                lr: lr as f32,
                v: vec![0.0; d],
            })),
            ServerOptSpec::Adam { b1, b2, eps, lr } => Some(Box::new(ServerAdam {
                b1,
                b2,
                eps,
                lr,
                t: 0,
                m: vec![0.0; d],
                v: vec![0.0; d],
            })),
        }
    }
}

/// A stateful server-side optimizer: consumes one aggregated round delta
/// Δ_t = s·Σ_r g_t^{(r)} (the plain-average descent step — "Avg" semantics
/// would be `x ← x − Δ_t`) and updates the global model in place.
pub trait ServerOpt: Send {
    /// Apply one round's aggregate `delta` to the model `x`.
    fn apply(&mut self, x: &mut [f32], delta: &[f32]);

    /// Override the step size used by subsequent [`ServerOpt::apply`] calls
    /// — the server-side LR-schedule hook
    /// (`MasterCore::set_server_lr_schedule` drives it once per round).
    /// Default: ignore, for optimizers without a step size.
    fn set_round_lr(&mut self, _lr: f64) {}

    fn name(&self) -> String;

    /// Serialize the optimizer's trajectory-dependent state (checkpointing).
    /// Spec-derived constants (betas, eps, base lr) are rebuilt from the
    /// spec on resume and are *not* written. Default: stateless.
    fn save_state(&self, w: &mut crate::compress::encode::BitWriter) {
        let _ = w;
    }

    /// Restore state written by [`ServerOpt::save_state`] onto a freshly
    /// built optimizer of the same spec and dimension. Default: nothing to
    /// read. Never panics on truncated input — errors are structured.
    fn load_state(
        &mut self,
        r: &mut crate::compress::encode::BitReader,
    ) -> Result<(), crate::compress::DecodeError> {
        let _ = r;
        Ok(())
    }
}

/// Server heavy-ball: `v ← β·v + Δ; x ← x − lr·v`.
struct ServerMomentum {
    beta: f32,
    lr: f32,
    v: Vec<f32>,
}

impl ServerOpt for ServerMomentum {
    fn apply(&mut self, x: &mut [f32], delta: &[f32]) {
        debug_assert_eq!(x.len(), delta.len());
        debug_assert_eq!(x.len(), self.v.len());
        for ((xi, di), vi) in x.iter_mut().zip(delta).zip(self.v.iter_mut()) {
            *vi = self.beta * *vi + di;
            *xi -= self.lr * *vi;
        }
    }

    fn set_round_lr(&mut self, lr: f64) {
        self.lr = lr as f32;
    }

    fn name(&self) -> String {
        format!("momentum(beta={},lr={})", self.beta, self.lr)
    }

    fn save_state(&self, w: &mut crate::compress::encode::BitWriter) {
        w.push_f32s(&self.v);
    }

    fn load_state(
        &mut self,
        r: &mut crate::compress::encode::BitReader,
    ) -> Result<(), crate::compress::DecodeError> {
        for vi in self.v.iter_mut() {
            *vi = r.read_f32().ok_or(crate::compress::DecodeError::Truncated)?;
        }
        Ok(())
    }
}

/// FedAdam: bias-corrected Adam on the round deltas.
struct ServerAdam {
    b1: f64,
    b2: f64,
    eps: f64,
    lr: f64,
    t: i32,
    m: Vec<f32>,
    v: Vec<f32>,
}

impl ServerOpt for ServerAdam {
    fn apply(&mut self, x: &mut [f32], delta: &[f32]) {
        debug_assert_eq!(x.len(), delta.len());
        self.t += 1;
        let (b1, b2) = (self.b1 as f32, self.b2 as f32);
        // Bias corrections in f64 (powi underflows late), applied as f32.
        let c1 = (1.0 / (1.0 - self.b1.powi(self.t))) as f32;
        let c2 = (1.0 / (1.0 - self.b2.powi(self.t))) as f32;
        let (lr, eps) = (self.lr as f32, self.eps as f32);
        for (((xi, di), mi), vi) in
            x.iter_mut().zip(delta).zip(self.m.iter_mut()).zip(self.v.iter_mut())
        {
            *mi = b1 * *mi + (1.0 - b1) * di;
            *vi = b2 * *vi + (1.0 - b2) * di * di;
            let mhat = *mi * c1;
            let vhat = *vi * c2;
            *xi -= lr * mhat / (vhat.sqrt() + eps);
        }
    }

    fn set_round_lr(&mut self, lr: f64) {
        self.lr = lr;
    }

    fn name(&self) -> String {
        format!("adam(b1={},b2={},eps={},lr={})", self.b1, self.b2, self.eps, self.lr)
    }

    fn save_state(&self, w: &mut crate::compress::encode::BitWriter) {
        w.push_bits(self.t as u64, 64);
        w.push_f32s(&self.m);
        w.push_f32s(&self.v);
    }

    fn load_state(
        &mut self,
        r: &mut crate::compress::encode::BitReader,
    ) -> Result<(), crate::compress::DecodeError> {
        use crate::compress::DecodeError;
        let t = r.read_bits(64).ok_or(DecodeError::Truncated)?;
        self.t = i32::try_from(t).map_err(|_| DecodeError::CountOverflow)?;
        for mi in self.m.iter_mut() {
            *mi = r.read_f32().ok_or(DecodeError::Truncated)?;
        }
        for vi in self.v.iter_mut() {
            *vi = r.read_f32().ok_or(DecodeError::Truncated)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn const_and_invtime() {
        let c = LrSchedule::Const { eta: 0.1 };
        assert_eq!(c.at(0), 0.1);
        assert_eq!(c.at(1000), 0.1);
        let it = LrSchedule::InvTime { xi: 8.0, a: 2.0 };
        assert!((it.at(0) - 4.0).abs() < 1e-12);
        assert!((it.at(6) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn warmup_piecewise() {
        let s = LrSchedule::WarmupPiecewise {
            peak: 1.0,
            warmup: 10,
            milestones: vec![30, 60],
            decay: 0.1,
        };
        assert!((s.at(0) - 0.1).abs() < 1e-12);
        assert!((s.at(9) - 1.0).abs() < 1e-12);
        assert!((s.at(20) - 1.0).abs() < 1e-12);
        assert!((s.at(30) - 0.1).abs() < 1e-12);
        assert!((s.at(60) - 0.01).abs() < 1e-12);
    }

    #[test]
    fn plain_sgd_step() {
        let mut opt = LocalSgd::plain(3);
        let mut x = vec![1.0f32, 2.0, 3.0];
        opt.step(&mut x, &[1.0, 0.0, -1.0], 0.5);
        assert_eq!(x, vec![0.5, 2.0, 3.5]);
    }

    #[test]
    fn momentum_accumulates() {
        let mut opt = LocalSgd::new(1, 0.9, 0.0);
        let mut x = vec![0.0f32];
        opt.step(&mut x, &[1.0], 1.0); // v=1, x=-1
        opt.step(&mut x, &[1.0], 1.0); // v=1.9, x=-2.9
        assert!((x[0] + 2.9).abs() < 1e-6);
    }

    #[test]
    fn weight_decay_pulls_to_zero() {
        let mut opt = LocalSgd::new(1, 0.0, 0.1);
        let mut x = vec![10.0f32];
        opt.step(&mut x, &[0.0], 1.0);
        assert!((x[0] - 9.0).abs() < 1e-6);
    }

    #[test]
    fn server_opt_spec_parse_and_roundtrip() {
        for (s, want) in [
            ("avg", ServerOptSpec::Avg),
            ("none", ServerOptSpec::Avg),
            ("momentum:0.9", ServerOptSpec::Momentum { beta: 0.9, lr: 1.0 - 0.9 }),
            ("momentum:beta=0.5", ServerOptSpec::Momentum { beta: 0.5, lr: 0.5 }),
            (
                "momentum:beta=0.9,lr=0.25",
                ServerOptSpec::Momentum { beta: 0.9, lr: 0.25 },
            ),
            (
                "adam",
                ServerOptSpec::Adam { b1: 0.9, b2: 0.99, eps: 1e-8, lr: 0.01 },
            ),
            (
                "adam:lr=0.1,eps=0.001",
                ServerOptSpec::Adam { b1: 0.9, b2: 0.99, eps: 0.001, lr: 0.1 },
            ),
        ] {
            let got = ServerOptSpec::parse(s).unwrap_or_else(|e| panic!("{s}: {e}"));
            assert_eq!(got, want, "{s}");
            // Canonical string round-trips exactly.
            assert_eq!(ServerOptSpec::parse(&got.spec_str()).unwrap(), got, "{s}");
        }
        for bad in [
            "bogus",
            "momentum",
            "momentum:beta=1.5",
            "momentum:beta=0.9,gamma=1",
            "momentum:0.9,beta=0.5",
            "adam:b1=2",
            "adam:0.9",
            "avg:x",
            "adam:lr=-1",
        ] {
            assert!(ServerOptSpec::parse(bad).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn server_momentum_matches_hand_computation() {
        // β=0.5, lr=1: v1 = Δ1, x1 = −Δ1; v2 = 0.5Δ1 + Δ2, x2 = x1 − v2.
        let mut opt = ServerOptSpec::Momentum { beta: 0.5, lr: 1.0 }.build(2).unwrap();
        let mut x = vec![0.0f32; 2];
        opt.apply(&mut x, &[1.0, -2.0]);
        assert_eq!(x, vec![-1.0, 2.0]);
        opt.apply(&mut x, &[1.0, 0.0]);
        // v = [1.5, -1.0] → x = [-1 - 1.5, 2 + 1.0]
        assert_eq!(x, vec![-2.5, 3.0]);
    }

    #[test]
    fn server_adam_first_step_is_lr_sized() {
        // Bias correction makes the very first Adam step ≈ lr·sign(Δ) for
        // |Δ| ≫ eps.
        let mut opt =
            ServerOptSpec::Adam { b1: 0.9, b2: 0.99, eps: 1e-8, lr: 0.05 }.build(3).unwrap();
        let mut x = vec![0.0f32; 3];
        opt.apply(&mut x, &[0.5, -2.0, 1e-3]);
        for (xi, di) in x.iter().zip([0.5f32, -2.0, 1e-3]) {
            assert!(
                (xi + 0.05 * di.signum()).abs() < 1e-3,
                "first step {xi} vs ±lr for delta {di}"
            );
        }
    }

    #[test]
    fn server_momentum_beta0_lr1_equals_plain_subtraction() {
        let mut opt = ServerOptSpec::Momentum { beta: 0.0, lr: 1.0 }.build(2).unwrap();
        let mut x = vec![3.0f32, -1.0];
        opt.apply(&mut x, &[0.5, 0.25]);
        assert_eq!(x, vec![2.5, -1.25]);
    }

    #[test]
    fn set_round_lr_rescales_subsequent_steps() {
        // β=0: each apply is exactly −lr·Δ, so the hook is directly visible.
        let mut opt = ServerOptSpec::Momentum { beta: 0.0, lr: 1.0 }.build(1).unwrap();
        let mut x = vec![0.0f32];
        opt.apply(&mut x, &[1.0]);
        assert_eq!(x, vec![-1.0]);
        opt.set_round_lr(0.5);
        opt.apply(&mut x, &[1.0]);
        assert_eq!(x, vec![-1.5]);
        let mut adam =
            ServerOptSpec::Adam { b1: 0.9, b2: 0.99, eps: 1e-8, lr: 0.05 }.build(1).unwrap();
        adam.set_round_lr(0.5);
        let mut y = vec![0.0f32];
        adam.apply(&mut y, &[1.0]);
        assert!((y[0] + 0.5).abs() < 1e-3, "first Adam step ≈ new lr: {}", y[0]);
    }

    #[test]
    fn avg_builds_nothing() {
        assert!(ServerOptSpec::Avg.build(8).is_none());
        assert!(ServerOptSpec::Avg.is_avg());
        assert!(!ServerOptSpec::Momentum { beta: 0.9, lr: 0.1 }.is_avg());
    }
}
