//! Artifact manifest (written by python/compile/aot.py, parsed here with the
//! in-tree JSON parser — serde is unavailable offline).

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::Path;

/// One exported model variant.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub name: String,
    pub kind: String,
    /// Flat parameter dimension.
    pub d: usize,
    /// Compiled per-call batch size.
    pub batch: usize,
    /// Feature width of x (classifiers: input dim; LM: seq+1 tokens as f32).
    pub feat: usize,
    pub classes: usize,
    pub grad_file: String,
    pub eval_file: String,
    pub init_file: Option<String>,
    /// LM-only: sequence length.
    pub seq: Option<usize>,
    /// Per-tensor flat sizes (LM), for piecewise compression.
    pub layer_sizes: Vec<usize>,
}

/// Parsed manifest.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub models: Vec<ModelEntry>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let root = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        anyhow::ensure!(
            root.get("format").as_usize() == Some(1),
            "unsupported manifest format"
        );
        let models = root
            .get("models")
            .as_arr()
            .context("manifest missing `models`")?
            .iter()
            .map(parse_entry)
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { models })
    }

    pub fn model(&self, name: &str) -> Option<&ModelEntry> {
        self.models.iter().find(|m| m.name == name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.models.iter().map(|m| m.name.as_str()).collect()
    }
}

fn parse_entry(j: &Json) -> Result<ModelEntry> {
    let req_str = |k: &str| -> Result<String> {
        j.get(k)
            .as_str()
            .map(str::to_string)
            .with_context(|| format!("manifest entry missing `{k}`"))
    };
    let req_usize = |k: &str| -> Result<usize> {
        j.get(k)
            .as_usize()
            .with_context(|| format!("manifest entry missing `{k}`"))
    };
    Ok(ModelEntry {
        name: req_str("name")?,
        kind: req_str("kind")?,
        d: req_usize("d")?,
        batch: req_usize("batch")?,
        feat: req_usize("feat")?,
        classes: req_usize("classes")?,
        grad_file: req_str("grad_file")?,
        eval_file: req_str("eval_file")?,
        init_file: j.get("init_file").as_str().map(str::to_string),
        seq: j.get("seq").as_usize(),
        layer_sizes: j
            .get("layer_sizes")
            .as_arr()
            .map(|a| a.iter().filter_map(Json::as_usize).collect())
            .unwrap_or_default(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "models": [
        {"name": "softmax", "kind": "softmax", "d": 7850, "batch": 8,
         "feat": 784, "classes": 10,
         "grad_file": "softmax.grad.hlo.txt", "eval_file": "softmax.eval.hlo.txt"},
        {"name": "lm", "kind": "lm", "d": 1000, "batch": 4, "feat": 65,
         "classes": 256, "seq": 64, "layer_sizes": [10, 20],
         "grad_file": "lm.grad.hlo.txt", "eval_file": "lm.eval.hlo.txt",
         "init_file": "lm.init.f32"}
      ]
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.names(), vec!["softmax", "lm"]);
        let s = m.model("softmax").unwrap();
        assert_eq!(s.d, 7850);
        assert_eq!(s.batch, 8);
        assert!(s.init_file.is_none());
        assert!(s.seq.is_none());
        let lm = m.model("lm").unwrap();
        assert_eq!(lm.seq, Some(64));
        assert_eq!(lm.layer_sizes, vec![10, 20]);
        assert_eq!(lm.init_file.as_deref(), Some("lm.init.f32"));
        assert!(m.model("nope").is_none());
    }

    #[test]
    fn rejects_bad_format() {
        assert!(Manifest::parse(r#"{"format": 2, "models": []}"#).is_err());
        assert!(Manifest::parse("{").is_err());
        assert!(Manifest::parse(r#"{"format": 1, "models": [{"name": "x"}]}"#).is_err());
    }
}
