//! PJRT runtime: load AOT artifacts (HLO text) and run them as `GradModel`s.
//!
//! `make artifacts` (python/compile/aot.py) lowers each L2 model — with its
//! L1 Pallas kernels inlined — to `artifacts/<name>.{grad,eval}.hlo.txt`
//! plus `manifest.json`. This module compiles those once per process on the
//! PJRT CPU client and exposes them behind the same `GradModel` trait the
//! native substrates implement, so the engine/coordinator are backend
//! agnostic. Python never runs at training time.
//!
//! # Feature gate
//!
//! The PJRT client comes from the `xla` bindings, which need a local XLA
//! extension build — an optional, heavyweight dependency. The crate
//! therefore compiles the real backend only under `--features pjrt`; the
//! default build ships an API-compatible stub whose `PjrtRuntime::open`
//! returns an error, so every CLI path, example and test that merely
//! *mentions* the runtime still compiles and runs (PJRT-dependent tests
//! skip themselves when artifacts are absent).
// `unsafe` lives only in the fork-join core (`engine::parallel`,
// `coordinator::master`) — everywhere else it is a compile error.
#![forbid(unsafe_code)]

pub mod manifest;

pub use manifest::{Manifest, ModelEntry};

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{PjrtModel, PjrtRuntime};

#[cfg(not(feature = "pjrt"))]
mod stub {
    use super::{Manifest, ModelEntry};
    use crate::data::Batch;
    use crate::grad::GradModel;
    use anyhow::Result;
    use std::path::Path;

    const NO_PJRT: &str =
        "qsparse was built without the `pjrt` feature; rebuild with `--features pjrt` \
         (requires the xla extension) to execute AOT artifacts";

    /// API-compatible stand-in for the PJRT runtime. Manifest-only flows
    /// (`qsparse inspect`, artifact listing) still work — parsing
    /// `manifest.json` needs no XLA; only loading/executing models errors.
    pub struct PjrtRuntime {
        manifest: Manifest,
    }

    impl PjrtRuntime {
        /// False in stub builds — lets callers (tests, benches) skip
        /// execution paths instead of panicking on `load_model` errors.
        pub fn backend_available() -> bool {
            false
        }

        pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
            let manifest = Manifest::load(dir.as_ref().join("manifest.json"))?;
            Ok(PjrtRuntime { manifest })
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn load_model(&self, _name: &str) -> Result<PjrtModel> {
            anyhow::bail!(NO_PJRT)
        }

        pub fn load_init(&self, _name: &str) -> Result<Option<Vec<f32>>> {
            anyhow::bail!(NO_PJRT)
        }
    }

    /// Stand-in for an AOT-compiled model; unconstructable through the
    /// public API (`open` errors first), so its methods are unreachable.
    pub struct PjrtModel {
        pub entry: ModelEntry,
    }

    impl PjrtModel {
        pub fn loss_grad_vec(&self, _params: &[f32], _batch: &Batch) -> Result<(f64, Vec<f32>)> {
            anyhow::bail!(NO_PJRT)
        }

        pub fn eval_metrics(&self, _params: &[f32], _batch: &Batch) -> Result<(f64, f64, f64)> {
            anyhow::bail!(NO_PJRT)
        }
    }

    impl GradModel for PjrtModel {
        fn dim(&self) -> usize {
            self.entry.d
        }

        fn loss_grad(&self, _params: &[f32], _batch: &Batch, _grad: &mut [f32]) -> f64 {
            panic!("{NO_PJRT}")
        }

        fn loss(&self, _params: &[f32], _batch: &Batch) -> f64 {
            panic!("{NO_PJRT}")
        }

        fn error_rate(&self, _params: &[f32], _batch: &Batch) -> f64 {
            panic!("{NO_PJRT}")
        }

        fn topn_error_rate(&self, _params: &[f32], _batch: &Batch, _n: usize) -> f64 {
            panic!("{NO_PJRT}")
        }

        fn name(&self) -> String {
            format!("pjrt-stub:{}", self.entry.name)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::{PjrtModel, PjrtRuntime};
