//! Real PJRT backend (`--features pjrt`): compiles the AOT HLO artifacts on
//! the PJRT CPU client via the `xla` bindings. See `runtime::` for the
//! feature gate and the artifact pipeline description.

use super::manifest::{Manifest, ModelEntry};
use crate::data::Batch;
use crate::grad::GradModel;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// A process-wide PJRT CPU client plus the artifact directory.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
}

impl PjrtRuntime {
    /// True: this build can compile and execute artifacts.
    pub fn backend_available() -> bool {
        true
    }

    /// Open `artifacts/` (must contain manifest.json) and create the client.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtRuntime { client, dir, manifest })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile the grad+eval executables of a model variant.
    pub fn load_model(&self, name: &str) -> Result<PjrtModel> {
        let entry = self
            .manifest
            .model(name)
            .with_context(|| format!("model `{name}` not in manifest"))?
            .clone();
        let grad = self.compile(&entry.grad_file)?;
        let eval = self.compile(&entry.eval_file)?;
        Ok(PjrtModel { entry, grad, eval })
    }

    /// Read the exported initial parameters (raw little-endian f32), if any.
    pub fn load_init(&self, name: &str) -> Result<Option<Vec<f32>>> {
        let entry = self
            .manifest
            .model(name)
            .with_context(|| format!("model `{name}` not in manifest"))?;
        let Some(init_file) = &entry.init_file else {
            return Ok(None);
        };
        let bytes = std::fs::read(self.dir.join(init_file))?;
        anyhow::ensure!(bytes.len() == entry.d * 4, "init file size mismatch");
        let mut out = Vec::with_capacity(entry.d);
        for c in bytes.chunks_exact(4) {
            out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok(Some(out))
    }

    fn compile(&self, file: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }
}

/// An AOT-compiled model variant: `(params, x, y) → (loss, grad)` plus the
/// `(loss, top1_errs, top5_errs)` evaluation executable.
pub struct PjrtModel {
    pub entry: ModelEntry,
    grad: xla::PjRtLoadedExecutable,
    eval: xla::PjRtLoadedExecutable,
}

impl PjrtModel {
    fn literals(&self, params: &[f32], batch: &Batch) -> Result<[xla::Literal; 3]> {
        anyhow::ensure!(
            params.len() == self.entry.d,
            "params len {} != artifact d {}",
            params.len(),
            self.entry.d
        );
        anyhow::ensure!(
            batch.b == self.entry.batch,
            "batch size {} != artifact batch {} (artifacts are shape-specialized)",
            batch.b,
            self.entry.batch
        );
        anyhow::ensure!(batch.dim == self.entry.feat, "feature dim mismatch");
        let p = xla::Literal::vec1(params);
        let x = xla::Literal::vec1(&batch.x)
            .reshape(&[batch.b as i64, batch.dim as i64])?;
        let y_i32: Vec<i32> = batch.y.iter().map(|&v| v as i32).collect();
        let y = xla::Literal::vec1(&y_i32);
        Ok([p, x, y])
    }

    /// Raw grad call: returns (loss, grad).
    pub fn loss_grad_vec(&self, params: &[f32], batch: &Batch) -> Result<(f64, Vec<f32>)> {
        let args = self.literals(params, batch)?;
        let result = self.grad.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let (loss, grad) = result.to_tuple2()?;
        let loss = loss.get_first_element::<f32>()? as f64;
        let grad = grad.to_vec::<f32>()?;
        Ok((loss, grad))
    }

    /// Raw eval call: returns (loss, top1_err_rate, top5_err_rate).
    pub fn eval_metrics(&self, params: &[f32], batch: &Batch) -> Result<(f64, f64, f64)> {
        let args = self.literals(params, batch)?;
        let result = self.eval.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let (loss, top1, top5) = result.to_tuple3()?;
        // The LM artifacts count errors over b·seq positions, classifiers
        // over b rows.
        let rows = self.eval_rows();
        Ok((
            loss.get_first_element::<f32>()? as f64,
            top1.get_first_element::<f32>()? as f64 / rows,
            top5.get_first_element::<f32>()? as f64 / rows,
        ))
    }

    fn eval_rows(&self) -> f64 {
        match self.entry.seq {
            Some(seq) => (self.entry.batch * seq) as f64,
            None => self.entry.batch as f64,
        }
    }

    /// Split an arbitrary batch into compiled-size chunks (≥1). Short batches
    /// are padded by repeating rows (only eval subsets hit this path).
    fn chunks(&self, batch: &Batch) -> Vec<Batch> {
        let cb = self.entry.batch;
        if batch.b == cb {
            return vec![batch.clone()];
        }
        let mut out = Vec::new();
        let mut i = 0;
        while i + cb <= batch.b {
            out.push(Batch {
                x: batch.x[i * batch.dim..(i + cb) * batch.dim].to_vec(),
                y: batch.y[i..i + cb].to_vec(),
                b: cb,
                dim: batch.dim,
            });
            i += cb;
        }
        if out.is_empty() {
            let mut x = batch.x.clone();
            let mut y = batch.y.clone();
            while y.len() < cb {
                let src = y.len() % batch.b;
                x.extend_from_slice(&batch.x[src * batch.dim..(src + 1) * batch.dim]);
                y.push(batch.y[src]);
            }
            out.push(Batch { x, y, b: cb, dim: batch.dim });
        }
        out
    }
}

impl GradModel for PjrtModel {
    fn dim(&self) -> usize {
        self.entry.d
    }

    fn loss_grad(&self, params: &[f32], batch: &Batch, grad: &mut [f32]) -> f64 {
        let (loss, g) = self
            .loss_grad_vec(params, batch)
            .expect("PJRT grad execution failed");
        grad.copy_from_slice(&g);
        loss
    }

    fn loss(&self, params: &[f32], batch: &Batch) -> f64 {
        let mut losses = Vec::new();
        for chunk in self.chunks(batch) {
            let (l, _, _) = self.eval_metrics(params, &chunk).expect("PJRT eval failed");
            losses.push(l);
        }
        losses.iter().sum::<f64>() / losses.len().max(1) as f64
    }

    fn error_rate(&self, params: &[f32], batch: &Batch) -> f64 {
        let mut errs = Vec::new();
        for chunk in self.chunks(batch) {
            let (_, e1, _) = self.eval_metrics(params, &chunk).expect("PJRT eval failed");
            errs.push(e1);
        }
        errs.iter().sum::<f64>() / errs.len().max(1) as f64
    }

    fn topn_error_rate(&self, params: &[f32], batch: &Batch, n: usize) -> f64 {
        let mut errs = Vec::new();
        for chunk in self.chunks(batch) {
            let (_, e1, e5) = self.eval_metrics(params, &chunk).expect("PJRT eval failed");
            errs.push(if n >= 5 { e5 } else { e1 });
        }
        errs.iter().sum::<f64>() / errs.len().max(1) as f64
    }

    fn name(&self) -> String {
        format!("pjrt:{}", self.entry.name)
    }
}
