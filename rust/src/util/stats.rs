//! Small statistics + timing helpers used by tests and the bench harness
//! (criterion is not available offline; `benches/*.rs` use these).

// The one sanctioned wall-clock module: everything here exists to *measure*
// time for benches/CLI reporting, never to influence a training trajectory.
// `clippy.toml` bans `Instant::now`/`SystemTime::now` everywhere else.
#![allow(clippy::disallowed_methods)]

use std::time::{Duration, Instant};

/// Summary statistics over a sample of f64s.
#[derive(Clone, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty());
        let mut sorted = samples.to_vec();
        // Total order so NaN samples (e.g. a probe that divided by a zero
        // count) summarize instead of panicking; NaNs sort after +inf.
        sorted.sort_by(f64::total_cmp);
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let q = |p: f64| sorted[((p * (n - 1) as f64).round() as usize).min(n - 1)];
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: q(0.5),
            p90: q(0.9),
            p99: q(0.99),
            max: sorted[n - 1],
        }
    }
}

/// Time `f` for `iters` iterations after `warmup` warmup iterations; returns
/// per-iteration durations in seconds.
pub fn time_iters<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64());
    }
    out
}

/// Bench-report line in a stable, grep-friendly format.
pub fn report(name: &str, samples_sec: &[f64], bytes_per_iter: Option<usize>) {
    let s = Summary::of(samples_sec);
    let mut line = format!(
        "bench {name:<44} n={:<4} mean={:>10} p50={:>10} p99={:>10}",
        s.n,
        fmt_duration(s.mean),
        fmt_duration(s.p50),
        fmt_duration(s.p99),
    );
    if let Some(b) = bytes_per_iter {
        let gbps = b as f64 / s.mean / 1e9;
        line.push_str(&format!(" thrpt={gbps:>7.3} GB/s"));
    }
    println!("{line}");
}

pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.3}ms", secs * 1e3)
    } else {
        format!("{:.3}s", secs)
    }
}

/// Simple wall-clock stopwatch.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }
    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

// -- vector helpers shared across modules ------------------------------------

/// Squared L2 norm.
pub fn norm2_sq(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum()
}

/// L2 norm.
pub fn norm2(xs: &[f32]) -> f64 {
    norm2_sq(xs).sqrt()
}

/// L1 norm.
pub fn norm1(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64).abs()).sum()
}

/// max |x_i|.
pub fn norm_inf(xs: &[f32]) -> f64 {
    xs.iter().fold(0.0f64, |m, &x| m.max((x as f64).abs()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_quantiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.n, 100);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.p50 - 50.0).abs() <= 1.0);
        assert!((s.p99 - 99.0).abs() <= 1.0);
    }

    #[test]
    fn summary_tolerates_nan_samples() {
        // total_cmp sorts (positive) NaN after every finite sample — no
        // panic, finite order statistics stay meaningful.
        let s = Summary::of(&[2.0, f64::NAN, 1.0, 3.0]);
        assert_eq!(s.n, 4);
        assert_eq!(s.min, 1.0);
        assert!(s.p50.is_finite());
        assert!(s.max.is_nan());
    }

    #[test]
    fn norms() {
        let v = [3.0f32, -4.0];
        assert!((norm2(&v) - 5.0).abs() < 1e-9);
        assert!((norm1(&v) - 7.0).abs() < 1e-9);
        assert!((norm_inf(&v) - 4.0).abs() < 1e-9);
        assert!((norm2_sq(&v) - 25.0).abs() < 1e-9);
    }

    #[test]
    fn fmt_duration_ranges() {
        assert!(fmt_duration(5e-9).ends_with("ns"));
        assert!(fmt_duration(5e-6).ends_with("us"));
        assert!(fmt_duration(5e-3).ends_with("ms"));
        assert!(fmt_duration(5.0).ends_with('s'));
    }
}
