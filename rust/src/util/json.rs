//! Minimal JSON value type, parser and emitter.
//!
//! serde is not available in the offline sandbox, so the library carries its
//! own small JSON implementation. It is used for (a) reading the artifact
//! manifest that `python/compile/aot.py` writes next to the HLO text files,
//! and (b) emitting metrics/result files the figure harness produces.
//!
//! Scope: full JSON per RFC 8259 minus `\u` surrogate-pair edge pedantry
//! (pairs are handled; lone surrogates are replaced). Numbers are f64.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]`-style access; returns Null for missing keys/non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // -- builders ------------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Pretty-print with 2-space indentation (objects keep their stable
    /// BTreeMap key order). Scalars and empty containers stay inline, so
    /// `parse(pretty()) == self` exactly like the compact `Display` form.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.pretty_into(&mut out, 0);
        out.push('\n');
        out
    }

    fn pretty_into(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    out.push_str(if i > 0 { ",\n" } else { "\n" });
                    push_indent(out, indent + 1);
                    v.pretty_into(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push(']');
            }
            Json::Obj(o) if !o.is_empty() => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    out.push_str(if i > 0 { ",\n" } else { "\n" });
                    push_indent(out, indent + 1);
                    out.push_str(&Json::Str(k.clone()).to_string());
                    out.push_str(": ");
                    v.pretty_into(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push('}');
            }
            scalar => out.push_str(&scalar.to_string()),
        }
    }
}

fn push_indent(out: &mut String, levels: usize) {
    for _ in 0..levels {
        out.push_str("  ");
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_byte(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect_byte(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            if self.bytes[self.pos..].starts_with(b"\\u") {
                                self.pos += 2;
                                let lo = self.hex4()?;
                                0x10000 + (((hi - 0xD800) as u32) << 10) + (lo - 0xDC00) as u32
                            } else {
                                0xFFFD
                            }
                        } else {
                            hi as u32
                        };
                        out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => out.push(b as char),
                Some(b) => {
                    // Multi-byte UTF-8: copy the remaining continuation bytes.
                    let len = if b >= 0xF0 {
                        4
                    } else if b >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u16, JsonError> {
        let mut v: u16 = 0;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))? as u16;
            v = (v << 4) | d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        // The scanned range is ASCII by construction, but a parser must never
        // trust its own scanner with a panic: malformed input surfaces as
        // `JsonError`, the same named error every other path returns.
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/Infinity tokens; follow
                    // `JSON.stringify` and emit null so one degenerate
                    // value (e.g. a NaN bench probe) cannot make the whole
                    // document unparseable.
                    write!(f, "null")
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let src = r#"{
          "models": [
            {"name": "softmax", "params": 7850, "batch": 8, "shapes": {"x": [8, 784]}},
            {"name": "mlp", "params": 203530, "lr": 0.5e-1, "flags": [true, false, null]}
          ],
          "note": "unicode é \t ok"
        }"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("models").as_arr().unwrap().len(), 2);
        assert_eq!(
            v.get("models").as_arr().unwrap()[0].get("params").as_usize(),
            Some(7850)
        );
        assert_eq!(v.get("note").as_str().unwrap(), "unicode é \t ok");
        // emit → parse fixpoint
        let emitted = v.to_string();
        let v2 = Json::parse(&emitted).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn non_finite_numbers_emit_null_not_invalid_tokens() {
        // A NaN probe value (e.g. a degenerate bench ratio) must not make
        // the emitted document unparseable.
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let doc = Json::obj(vec![("v", Json::num(bad)), ("ok", Json::num(1.5))]);
            let emitted = doc.to_string();
            let back = Json::parse(&emitted).unwrap_or_else(|e| {
                panic!("emitted JSON unparseable for {bad}: {e:?} ({emitted})")
            });
            assert!(matches!(back.get("v"), Json::Null), "{emitted}");
            assert_eq!(back.get("ok").as_f64(), Some(1.5));
        }
    }

    #[test]
    fn numbers() {
        for (s, want) in [
            ("0", 0.0),
            ("-1", -1.0),
            ("3.25", 3.25),
            ("1e3", 1000.0),
            ("-2.5E-2", -0.025),
        ] {
            assert_eq!(Json::parse(s).unwrap().as_f64(), Some(want), "{s}");
        }
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,", "{\"a\":}", "tru", "1 2", "\"\\x\""] {
            assert!(Json::parse(s).is_err(), "should reject {s:?}");
        }
    }

    #[test]
    fn surrogate_pair() {
        let v = Json::parse("\"\\ud83d\\ude00\"").unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn pretty_roundtrips_and_indents() {
        let v = Json::parse(
            r#"{"b": [1, 2, {"x": null}], "a": "s\"tr", "empty": [], "eobj": {}, "n": 1.5}"#,
        )
        .unwrap();
        let p = v.pretty();
        assert_eq!(Json::parse(&p).unwrap(), v);
        assert!(p.contains("\n  \"a\": \"s\\\"tr\""), "{p}");
        assert!(p.contains("\"empty\": []"), "{p}");
        assert!(p.ends_with("}\n"), "{p}");
    }

    #[test]
    fn get_on_missing_is_null() {
        let v = Json::parse(r#"{"a": 1}"#).unwrap();
        assert_eq!(v.get("b"), &Json::Null);
        assert_eq!(v.get("b").as_f64(), None);
    }

    #[test]
    fn malformed_numbers_error_never_panic() {
        // Regression for the `from_utf8(..).unwrap()` that used to sit in
        // `number()`: every malformed numeric token must come back as a
        // `JsonError`, no matter how the scanner was led astray.
        for bad in [
            "-",
            "-.",
            ".5",
            "1e",
            "1e+",
            "-e5",
            "--3",
            "1.2.3",
            "0x10",
            "+1",
            r#"{"lr": -}"#,
            r#"{"lr": 1e}"#,
            r#"[1, 2, -]"#,
            r#"{"a": 1eé}"#,
        ] {
            let r = Json::parse(bad);
            assert!(r.is_err(), "accepted malformed input {bad:?}: {r:?}");
        }
    }
}
