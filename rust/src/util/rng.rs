//! Deterministic pseudo-random number generation.
//!
//! The sandbox ships no `rand` crate, so we implement the small set of
//! primitives the library needs: a seedable 64-bit PCG (PCG-XSH-RR variant
//! on a 128-bit LCG state, O'Neill 2014), uniform ints/floats, Box-Muller
//! normals, Fisher-Yates shuffle and sampling without replacement.
//!
//! Every stochastic component in the library (data generation, minibatch
//! sampling, Rand_k, stochastic quantizers, async schedules) takes an
//! explicit `Pcg64` so whole training runs are reproducible from one seed.

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Distinct streams are
    /// statistically independent, which we use to give every worker its own
    /// stream derived from (run seed, worker id).
    pub fn new(seed: u64, stream: u64) -> Self {
        // SplitMix64 the inputs so nearby seeds diverge immediately.
        let mut sm = SplitMix64::new(seed ^ 0x9e3779b97f4a7c15);
        let s0 = sm.next_u64() as u128;
        let s1 = sm.next_u64() as u128;
        let mut sm2 = SplitMix64::new(stream.wrapping_mul(0xda942042e4dd58b5) ^ 0x5851f42d4c957f2d);
        let i0 = sm2.next_u64() as u128;
        let i1 = sm2.next_u64() as u128;
        let mut rng = Pcg64 {
            state: (s0 << 64) | s1,
            inc: (((i0 << 64) | i1) << 1) | 1, // must be odd
        };
        // Warm up.
        rng.next_u64();
        rng.next_u64();
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Raw `(state, inc)` pair for checkpointing. `restore`-ing it resumes
    /// the stream exactly where `snapshot` left it.
    pub fn snapshot(&self) -> (u128, u128) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from a [`Pcg64::snapshot`] pair. `inc` must be
    /// odd (every generator this crate constructs has an odd increment);
    /// callers deserializing untrusted bytes check that before calling.
    pub fn restore(state: u128, inc: u128) -> Self {
        debug_assert!(inc & 1 == 1, "pcg increment must be odd");
        Pcg64 { state, inc }
    }

    /// Derive a child generator (e.g. per worker / per step) without
    /// perturbing this generator's own sequence more than one draw.
    pub fn fork(&mut self, stream: u64) -> Pcg64 {
        let s = self.next_u64();
        Pcg64::new(s, stream)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        // XSL-RR output function.
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in [lo, hi] inclusive.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.f32() * (hi - lo)
    }

    /// Standard normal via Box-Muller (uses two uniforms per pair; we keep
    /// the spare for the next call).
    pub fn normal(&mut self) -> f64 {
        // Marsaglia polar method: no trig in the hot path, rejection rate ~21%.
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let factor = (-2.0 * s.ln() / s).sqrt();
                return u * factor;
            }
        }
    }

    /// Standard normal as f32.
    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with N(0, sigma^2) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32() * sigma;
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        if n < 2 {
            return;
        }
        for i in (1..n).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) uniformly (Floyd's algorithm for
    /// small k, partial shuffle otherwise). Result order is unspecified.
    // The HashSet is membership-only scratch: its (RandomState) iteration
    // order is never observed, so determinism is unaffected (allowed
    // exception to the `clippy.toml` hash-container ban).
    #[allow(clippy::disallowed_types)]
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        if k == 0 {
            return Vec::new();
        }
        if k * 4 <= n {
            // Floyd: O(k) expected with a small hash set.
            let mut chosen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            for j in (n - k)..n {
                let t = self.below_usize(j + 1);
                if chosen.insert(t) {
                    out.push(t);
                } else {
                    chosen.insert(j);
                    out.push(j);
                }
            }
            out
        } else {
            let mut idx: Vec<usize> = (0..n).collect();
            for i in 0..k {
                let j = i + self.below_usize(n - i);
                idx.swap(i, j);
            }
            idx.truncate(k);
            idx
        }
    }
}

/// SplitMix64 — used only for seeding.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_types)] // hash containers as assertion scratch only
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_stream_separated() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 0);
        let mut c = Pcg64::new(42, 1);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn snapshot_restore_resumes_the_stream() {
        let mut rng = Pcg64::new(99, 3);
        let _ = (0..17).map(|_| rng.next_u64()).count();
        let (state, inc) = rng.snapshot();
        let tail: Vec<u64> = (0..16).map(|_| rng.next_u64()).collect();
        let mut resumed = Pcg64::restore(state, inc);
        let resumed_tail: Vec<u64> = (0..16).map(|_| resumed.next_u64()).collect();
        assert_eq!(tail, resumed_tail);
    }

    #[test]
    fn uniform_below_is_in_range_and_covers() {
        let mut rng = Pcg64::seeded(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::seeded(3);
        for _ in 0..1000 {
            let v = rng.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let v = rng.normal();
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn sample_indices_distinct_and_complete() {
        let mut rng = Pcg64::seeded(5);
        for &(n, k) in &[(100usize, 5usize), (100, 90), (10, 10), (1, 1), (50, 0)] {
            let idx = rng.sample_indices(n, k);
            assert_eq!(idx.len(), k);
            let set: std::collections::HashSet<_> = idx.iter().collect();
            assert_eq!(set.len(), k, "duplicates for n={n} k={k}");
            assert!(idx.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(9);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
