//! Support substrates (offline sandbox: these replace the usual crates —
//! see DESIGN.md §6 Substitutions).
// `unsafe` lives only in the fork-join core (`engine::parallel`,
// `coordinator::master`) — everywhere else it is a compile error.
#![forbid(unsafe_code)]

pub mod json;
pub mod rng;
pub mod stats;
