//! Support substrates (offline sandbox: these replace the usual crates —
//! see DESIGN.md §6 Substitutions).

pub mod json;
pub mod rng;
pub mod stats;
