//! Datasets, synthetic generators and worker sharding.
//!
//! The sandbox has no dataset downloads, so the paper's MNIST/ImageNet
//! workloads are substituted by synthetic classification data with matched
//! dimensions (DESIGN.md §6): `gaussian_clusters` draws class means on a
//! sphere and samples isotropic Gaussians around them — a 10-class problem
//! with 784 features reproduces the d = (784+1)·10 = 7850 softmax geometry
//! of the paper's convex experiments.
// `unsafe` lives only in the fork-join core (`engine::parallel`,
// `coordinator::master`) — everywhere else it is a compile error.
#![forbid(unsafe_code)]

use crate::util::rng::Pcg64;

/// An in-memory classification dataset: row-major features + integer labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub features: Vec<f32>,
    pub labels: Vec<u32>,
    pub n: usize,
    pub dim: usize,
    pub classes: usize,
}

impl Dataset {
    pub fn row(&self, i: usize) -> &[f32] {
        &self.features[i * self.dim..(i + 1) * self.dim]
    }

    /// Gather rows `idx` into a dense batch (x: b×dim, y: b).
    pub fn gather(&self, idx: &[usize]) -> Batch {
        let mut out = Batch::empty();
        self.gather_into(idx, &mut out);
        out
    }

    /// Gather rows `idx` into a reusable batch (cleared and refilled) — the
    /// hot-path variant of `gather`: once the batch has reached capacity,
    /// repeated gathers perform no heap allocation.
    pub fn gather_into(&self, idx: &[usize], out: &mut Batch) {
        out.x.clear();
        out.y.clear();
        out.x.reserve(idx.len() * self.dim);
        out.y.reserve(idx.len());
        for &i in idx {
            out.x.extend_from_slice(self.row(i));
            out.y.push(self.labels[i]);
        }
        out.b = idx.len();
        out.dim = self.dim;
    }
}

/// A minibatch (row-major features).
#[derive(Clone, Debug, Default)]
pub struct Batch {
    pub x: Vec<f32>,
    pub y: Vec<u32>,
    pub b: usize,
    pub dim: usize,
}

impl Batch {
    /// An empty batch, ready to be filled via `Dataset::gather_into` /
    /// `ShardSampler::next_batch_into` (per-worker scratch).
    pub fn empty() -> Batch {
        Batch::default()
    }
}

/// Synthetic multi-class data: class means drawn N(0, I)·sep, points
/// N(mean, noise²·I). Labels balanced round-robin.
pub fn gaussian_clusters(
    n: usize,
    dim: usize,
    classes: usize,
    sep: f32,
    noise: f32,
    seed: u64,
) -> Dataset {
    let mut rng = Pcg64::new(seed, 77);
    let mut means = vec![0.0f32; classes * dim];
    rng.fill_normal(&mut means, sep);
    let mut features = vec![0.0f32; n * dim];
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let c = i % classes;
        labels.push(c as u32);
        let row = &mut features[i * dim..(i + 1) * dim];
        rng.fill_normal(row, noise);
        for (r, m) in row.iter_mut().zip(&means[c * dim..(c + 1) * dim]) {
            *r += *m;
        }
    }
    // Shuffle rows so shards are not trivially ordered by class.
    let mut perm: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut perm);
    let mut ds = Dataset { features: vec![0.0; n * dim], labels: vec![0; n], n, dim, classes };
    for (dst, &src) in perm.iter().enumerate() {
        ds.features[dst * dim..(dst + 1) * dim]
            .copy_from_slice(&features[src * dim..(src + 1) * dim]);
        ds.labels[dst] = labels[src];
    }
    ds
}

/// As `gaussian_clusters`, but returns a (train, test) pair drawn from the
/// *same* class means (generate once, split) — the correct held-out setup.
pub fn gaussian_clusters_split(
    n_train: usize,
    n_test: usize,
    dim: usize,
    classes: usize,
    sep: f32,
    noise: f32,
    seed: u64,
) -> (Dataset, Dataset) {
    let full = gaussian_clusters(n_train + n_test, dim, classes, sep, noise, seed);
    let train_idx: Vec<usize> = (0..n_train).collect();
    let test_idx: Vec<usize> = (n_train..n_train + n_test).collect();
    let take = |idx: &[usize]| {
        let b = full.gather(idx);
        Dataset {
            features: b.x,
            labels: b.y,
            n: idx.len(),
            dim,
            classes,
        }
    };
    (take(&train_idx), take(&test_idx))
}

/// Synthetic next-token corpus for the transformer driver: integer tokens
/// with a planted bigram structure so the LM loss has signal to descend.
pub fn synthetic_corpus(n_tokens: usize, vocab: usize, seed: u64) -> Vec<u32> {
    let mut rng = Pcg64::new(seed, 99);
    // Random sparse bigram table: each token has a small set of likely successors.
    let succ: Vec<[u32; 4]> = (0..vocab)
        .map(|_| {
            [
                rng.below(vocab as u64) as u32,
                rng.below(vocab as u64) as u32,
                rng.below(vocab as u64) as u32,
                rng.below(vocab as u64) as u32,
            ]
        })
        .collect();
    let mut out = Vec::with_capacity(n_tokens);
    let mut cur = rng.below(vocab as u64) as u32;
    for _ in 0..n_tokens {
        out.push(cur);
        cur = if rng.f32() < 0.8 {
            succ[cur as usize][rng.below(4) as usize]
        } else {
            rng.below(vocab as u64) as u32
        };
    }
    out
}

/// How a dataset is partitioned across R workers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sharding {
    /// Round-robin rows (IID shards).
    Iid,
    /// Sort by label, then contiguous blocks (pathological heterogeneity —
    /// the federated-learning stress case).
    LabelSkew,
}

impl Sharding {
    /// Parse the spec token: `iid` | `label-skew`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "iid" => Ok(Sharding::Iid),
            "label-skew" | "label_skew" | "skew" => Ok(Sharding::LabelSkew),
            other => anyhow::bail!("unknown sharding `{other}` (expected iid | label-skew)"),
        }
    }

    /// Canonical spec token — `parse(spec_str(s)) == s`.
    pub fn spec_str(&self) -> &'static str {
        match self {
            Sharding::Iid => "iid",
            Sharding::LabelSkew => "label-skew",
        }
    }
}

/// Partition row indices across workers.
pub fn shard_indices(ds: &Dataset, workers: usize, sharding: Sharding) -> Vec<Vec<usize>> {
    assert!(workers >= 1);
    let order: Vec<usize> = match sharding {
        Sharding::Iid => (0..ds.n).collect(),
        Sharding::LabelSkew => {
            let mut idx: Vec<usize> = (0..ds.n).collect();
            idx.sort_by_key(|&i| ds.labels[i]);
            idx
        }
    };
    let mut shards = vec![Vec::with_capacity(ds.n / workers + 1); workers];
    match sharding {
        Sharding::Iid => {
            for (j, &i) in order.iter().enumerate() {
                shards[j % workers].push(i);
            }
        }
        Sharding::LabelSkew => {
            let per = ds.n.div_ceil(workers);
            for (j, &i) in order.iter().enumerate() {
                shards[(j / per).min(workers - 1)].push(i);
            }
        }
    }
    shards
}

/// Per-worker uniform-with-replacement minibatch sampler over a shard
/// (matches the paper: "i_t^(r) is a mini-batch of size b uniformly in D_r").
#[derive(Clone, Debug)]
pub struct ShardSampler {
    shard: Vec<usize>,
    rng: Pcg64,
    pub batch: usize,
}

impl ShardSampler {
    pub fn new(shard: Vec<usize>, batch: usize, seed: u64, worker: usize) -> Self {
        assert!(!shard.is_empty(), "empty shard for worker {worker}");
        ShardSampler { shard, rng: Pcg64::new(seed ^ 0xbeef, worker as u64 + 101), batch }
    }

    pub fn next_batch(&mut self, ds: &Dataset) -> Batch {
        let mut out = Batch::empty();
        self.next_batch_into(ds, &mut out);
        out
    }

    /// Sample the next minibatch directly into a reusable batch — identical
    /// RNG draws and rows as `next_batch`, but no index vector and no fresh
    /// `Batch`, so steady-state sampling is allocation-free.
    pub fn next_batch_into(&mut self, ds: &Dataset, out: &mut Batch) {
        out.x.clear();
        out.y.clear();
        out.x.reserve(self.batch * ds.dim);
        out.y.reserve(self.batch);
        for _ in 0..self.batch {
            let i = self.shard[self.rng.below_usize(self.shard.len())];
            out.x.extend_from_slice(ds.row(i));
            out.y.push(ds.labels[i]);
        }
        out.b = self.batch;
        out.dim = ds.dim;
    }

    /// The sampling RNG stream — sampling is with replacement, so this is
    /// the sampler's only trajectory-dependent state (checkpointing).
    pub fn rng(&self) -> &Pcg64 {
        &self.rng
    }

    pub fn rng_mut(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_types)] // hash containers as assertion scratch only
mod tests {
    use super::*;

    #[test]
    fn clusters_shapes_and_balance() {
        let ds = gaussian_clusters(1000, 16, 10, 1.0, 0.3, 42);
        assert_eq!(ds.n, 1000);
        assert_eq!(ds.features.len(), 1000 * 16);
        let mut counts = [0usize; 10];
        for &l in &ds.labels {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 100));
    }

    #[test]
    fn clusters_are_separable_by_nearest_mean() {
        // With large separation and small noise a trivial classifier works —
        // sanity check that labels correlate with geometry.
        let ds = gaussian_clusters(500, 8, 5, 2.0, 0.1, 7);
        // Recompute class means from the data itself.
        let mut means = vec![0.0f64; 5 * 8];
        let mut counts = [0usize; 5];
        for i in 0..ds.n {
            let c = ds.labels[i] as usize;
            counts[c] += 1;
            for j in 0..8 {
                means[c * 8 + j] += ds.row(i)[j] as f64;
            }
        }
        for c in 0..5 {
            for j in 0..8 {
                means[c * 8 + j] /= counts[c] as f64;
            }
        }
        let mut correct = 0;
        for i in 0..ds.n {
            let mut best = (f64::MAX, 0usize);
            for c in 0..5 {
                let d2: f64 = (0..8)
                    .map(|j| (ds.row(i)[j] as f64 - means[c * 8 + j]).powi(2))
                    .sum();
                if d2 < best.0 {
                    best = (d2, c);
                }
            }
            correct += usize::from(best.1 == ds.labels[i] as usize);
        }
        assert!(correct as f64 / ds.n as f64 > 0.95);
    }

    #[test]
    fn iid_shards_partition() {
        let ds = gaussian_clusters(103, 4, 3, 1.0, 0.5, 1);
        let shards = shard_indices(&ds, 4, Sharding::Iid);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, 103);
        let mut all: Vec<usize> = shards.concat();
        all.sort_unstable();
        assert_eq!(all, (0..103).collect::<Vec<_>>());
    }

    #[test]
    fn label_skew_concentrates_labels() {
        let ds = gaussian_clusters(1000, 4, 10, 1.0, 0.5, 2);
        let shards = shard_indices(&ds, 10, Sharding::LabelSkew);
        // Each shard should be dominated by ~1 label.
        for shard in &shards {
            let mut counts = [0usize; 10];
            for &i in shard {
                counts[ds.labels[i] as usize] += 1;
            }
            let max = *counts.iter().max().unwrap();
            assert!(max * 10 >= shard.len() * 9, "shard not skewed: {counts:?}");
        }
    }

    #[test]
    fn sampler_batches_from_own_shard() {
        let ds = gaussian_clusters(100, 4, 2, 1.0, 0.5, 3);
        let shards = shard_indices(&ds, 2, Sharding::Iid);
        let allowed: std::collections::HashSet<Vec<u8>> = shards[0]
            .iter()
            .map(|&i| ds.row(i).iter().flat_map(|f| f.to_le_bytes()).collect())
            .collect();
        let mut s = ShardSampler::new(shards[0].clone(), 8, 9, 0);
        for _ in 0..5 {
            let b = s.next_batch(&ds);
            assert_eq!(b.b, 8);
            for r in 0..b.b {
                let row: Vec<u8> = b.x[r * 4..(r + 1) * 4]
                    .iter()
                    .flat_map(|f| f.to_le_bytes())
                    .collect();
                assert!(allowed.contains(&row));
            }
        }
    }

    #[test]
    fn corpus_token_range() {
        let toks = synthetic_corpus(10_000, 64, 5);
        assert_eq!(toks.len(), 10_000);
        assert!(toks.iter().all(|&t| t < 64));
        // Bigram structure: repeated pairs occur far above uniform chance.
        let mut pair_counts = std::collections::HashMap::new();
        for w in toks.windows(2) {
            *pair_counts.entry((w[0], w[1])).or_insert(0usize) += 1;
        }
        let max_pair = pair_counts.values().copied().max().unwrap();
        assert!(max_pair > 10, "no planted structure: {max_pair}");
    }
}
