//! Figure-regeneration bench: runs every paper figure in quick mode and
//! prints the paper-style summaries (the full-fidelity run is
//! `qsparse figure all`; see EXPERIMENTS.md for the recorded full run).
//!
//! `cargo bench --bench figures` — add `-- --full` for full fidelity.

use qsparse::figures;
use qsparse::util::stats::Stopwatch;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let quick = !full;
    println!(
        "# regenerating all paper figures ({} mode)\n",
        if quick { "quick" } else { "full" }
    );
    let total = Stopwatch::start();
    for id in figures::all_figure_ids() {
        let spec = figures::figure_spec(id).unwrap();
        let sw = Stopwatch::start();
        match figures::run_figure(&spec, quick) {
            Ok(result) => {
                result.write_csvs("results").ok();
                print!("{}", result.summary());
                println!("   ({:.1}s)\n", sw.secs());
            }
            Err(e) => println!("{id}: ERROR {e}\n"),
        }
    }
    println!("# γ table (d=7850, k=40)");
    for (name, gamma, measured) in figures::gamma_table(7850, 40) {
        println!("{name:<28} γ={gamma:<12.6} measured(1-γ̂)={measured:.6}");
    }
    println!("\ntotal: {:.1}s", total.secs());
}
