//! Compression-stack microbenchmarks (the L3 hot path).
//!
//! criterion is unavailable offline; this is a `harness = false` bench using
//! the in-tree timing harness (`util::stats`). Run with `cargo bench`.
//!
//! Dimensions: 25.6M mirrors ResNet-50 (the paper's non-convex model);
//! 7850 mirrors the convex workload. Reported GB/s is input throughput.

use qsparse::compress::{encode, parse_spec, ErrorMemory};
use qsparse::util::rng::Pcg64;
use qsparse::util::stats::{report, time_iters};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let big_d = if quick { 1 << 20 } else { 25_610_216 }; // ResNet-50 d
    let small_d = 7850;

    let mut rng = Pcg64::seeded(42);
    let big: Vec<f32> = (0..big_d).map(|_| rng.normal_f32()).collect();
    let small: Vec<f32> = (0..small_d).map(|_| rng.normal_f32()).collect();
    let bytes_big = big_d * 4;
    let (warm, iters) = if quick { (1, 3) } else { (2, 8) };

    println!("# compressor microbenches (d_big={big_d}, d_small={small_d})\n");
    let k_big = big_d / 256; // ~0.4%, the paper's ResNet-50 ratio
    for spec in [
        format!("topk:k={k_big}"),
        format!("randk:k={k_big}"),
        "qsgd:bits=4".to_string(),
        "sign".to_string(),
        format!("qtopk:k={k_big},bits=4"),
        format!("signtopk:k={k_big},m=1"),
    ] {
        let op = parse_spec(&spec).unwrap();
        let mut r = Pcg64::seeded(7);
        let samples = time_iters(warm, iters, || {
            std::hint::black_box(op.compress(&big, &mut r));
        });
        report(&format!("compress/{}", op.name()), &samples, Some(bytes_big));
    }

    println!();
    // Error-feedback round (compress + memory update) at ResNet scale.
    for spec in [format!("topk:k={k_big}"), format!("signtopk:k={k_big},m=1")] {
        let op = parse_spec(&spec).unwrap();
        let mut mem = ErrorMemory::zeros(big_d);
        let mut r = Pcg64::seeded(9);
        let samples = time_iters(warm, iters, || {
            std::hint::black_box(mem.compress_update(&big, op.as_ref(), &mut r));
        });
        report(&format!("ef-round/{}", op.name()), &samples, Some(bytes_big));
    }

    println!();
    // Wire encode/decode throughput.
    for spec in [
        format!("topk:k={k_big}"),
        format!("qtopk:k={k_big},bits=4"),
        format!("signtopk:k={k_big},m=1"),
    ] {
        let op = parse_spec(&spec).unwrap();
        let mut r = Pcg64::seeded(11);
        let msg = op.compress(&big, &mut r);
        let samples = time_iters(warm, iters, || {
            std::hint::black_box(encode::encode(&msg));
        });
        let (bytes, len) = encode::encode(&msg);
        report(
            &format!("encode/{}", op.name()),
            &samples,
            Some((len / 8) as usize),
        );
        let samples = time_iters(warm, iters, || {
            std::hint::black_box(encode::decode(&bytes, len));
        });
        report(
            &format!("decode/{}", op.name()),
            &samples,
            Some((len / 8) as usize),
        );
    }

    println!();
    // Convex-scale end-to-end compressor latency (tiny vectors, per-sync cost).
    for spec in ["topk:k=40", "signtopk:k=40,m=1", "qtopk:k=40,bits=4,scaled"] {
        let op = parse_spec(spec).unwrap();
        let mut r = Pcg64::seeded(13);
        let samples = time_iters(warm * 50, iters * 200, || {
            std::hint::black_box(op.compress(&small, &mut r));
        });
        report(&format!("small/{}", op.name()), &samples, Some(small_d * 4));
    }
}
