//! End-to-end step latency: native vs PJRT backends, the coordinator
//! overhead on top of raw gradient compute (DESIGN.md §Perf L3 target:
//! coordination ≤ 10% of step time), the parallel engine's scaling, and the
//! hot path's steady-state allocation count.
//!
//! Flags:
//!   --quick   fewer iterations (CI)
//!   --json    additionally write `BENCH_train_step.json`
//!             (name → {mean, p50, iters}) so the perf trajectory is
//!             machine-readable and accumulates per PR.
//!
//! The binary installs a counting global allocator; `alloc/...` entries
//! report steady-state heap allocations per engine step (measured as the
//! difference between a 2N-step and an N-step run, so setup and final-eval
//! allocations cancel exactly). The sequential engine's compress → encode →
//! fold path is allocation-free: expect 0 for `threads=1`.

// Benches are separate crates, so the library's crate-level deny does not
// reach them; re-assert it here for the counting allocator below.
#![deny(unsafe_op_in_unsafe_fn)]

use qsparse::compress::{encode, parse_spec, Codec, Compressor, MessageBuf, WireEncoder};
use qsparse::data::{gaussian_clusters, Dataset, Sharding};
use qsparse::engine::{run, TrainSpec};
use qsparse::grad::{GradModel, Mlp, SoftmaxRegression};
use qsparse::optim::LrSchedule;
use qsparse::runtime::PjrtRuntime;
use qsparse::sim::{self, EventQueue, SimSpec};
use qsparse::topology::FixedPeriod;
use qsparse::util::json::Json;
use qsparse::util::rng::Pcg64;
use qsparse::util::stats::{fmt_duration, report, time_iters, Summary};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// Counting allocator: every alloc/realloc bumps a global counter (frees are
/// not counted — the probe is "how often does the hot loop hit the heap").
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

// SAFETY: a pass-through wrapper over `System`. Each method forwards its
// arguments unchanged, so `System`'s own `GlobalAlloc` contract (layout
// validity, pointer provenance) is exactly preserved; the counter bump is a
// relaxed atomic with no effect on allocation behavior.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: forwards the caller's contract to `System` (impl-level SAFETY)
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: caller upholds `GlobalAlloc::alloc`'s contract for
        // `layout`; we forward it verbatim.
        unsafe { System.alloc(layout) }
    }

    // SAFETY: forwards the caller's contract to `System` (impl-level SAFETY)
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: caller upholds `GlobalAlloc::alloc_zeroed`'s contract.
        unsafe { System.alloc_zeroed(layout) }
    }

    // SAFETY: forwards the caller's contract to `System` (impl-level SAFETY)
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: caller guarantees `ptr` came from this allocator (which
        // forwards to `System`) with `layout`, and `new_size` is nonzero.
        unsafe { System.realloc(ptr, layout, new_size) }
    }

    // SAFETY: forwards the caller's contract to `System` (impl-level SAFETY)
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // SAFETY: caller guarantees `ptr`/`layout` came from this allocator,
        // i.e. from `System`.
        unsafe { System.dealloc(ptr, layout) }
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn count_allocs(f: impl FnOnce()) -> u64 {
    let before = ALLOCS.load(Ordering::Relaxed);
    f();
    ALLOCS.load(Ordering::Relaxed) - before
}

/// Collects every reported number so `--json` can dump the machine-readable
/// trajectory next to the human-readable lines.
struct Recorder {
    entries: Vec<(String, f64, f64, usize)>,
}

impl Recorder {
    fn new() -> Self {
        Recorder { entries: Vec::new() }
    }

    /// Print the standard bench line and record (mean, p50, n).
    fn report(&mut self, name: &str, samples: &[f64], bytes_per_iter: Option<usize>) -> f64 {
        report(name, samples, bytes_per_iter);
        let s = Summary::of(samples);
        self.entries.push((name.to_string(), s.mean, s.p50, s.n));
        s.mean
    }

    /// Record a scalar (counters, ratios) as a degenerate entry.
    fn value(&mut self, name: &str, v: f64) {
        println!("bench {name:<44} value={v}");
        self.entries.push((name.to_string(), v, v, 1));
    }

    fn write_json(&self, path: &str) {
        let obj = Json::obj(
            self.entries
                .iter()
                .map(|(name, mean, p50, iters)| {
                    (
                        name.as_str(),
                        Json::obj(vec![
                            ("mean", Json::num(*mean)),
                            ("p50", Json::num(*p50)),
                            ("iters", Json::from(*iters)),
                        ]),
                    )
                })
                .collect(),
        );
        match std::fs::write(path, format!("{obj}\n")) {
            Ok(()) => println!("\nwrote {path} ({} entries)", self.entries.len()),
            // Fatal: CI gates on this file — exiting 0 with a stale (or
            // committed seed) file on disk would validate numbers this run
            // never produced.
            Err(e) => {
                eprintln!("failed to write {path}: {e}");
                std::process::exit(1);
            }
        }
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let json = std::env::args().any(|a| a == "--json");
    let (warm, iters) = if quick { (1, 3) } else { (2, 10) };
    let mut rec = Recorder::new();

    // Raw gradient latency — the floor the coordinator adds to.
    let ds = gaussian_clusters(2000, 784, 10, 0.2, 1.0, 1);
    let softmax = SoftmaxRegression::new(784, 10, 1e-4);
    let batch = ds.gather(&(0..8).collect::<Vec<_>>());
    let mut params = vec![0.01f32; softmax.dim()];
    let mut grad = vec![0.0f32; softmax.dim()];
    let samples = time_iters(warm * 20, iters * 50, || {
        std::hint::black_box(softmax.loss_grad(&params, &batch, &mut grad));
    });
    let native_softmax_grad = rec.report("grad/native-softmax(b=8,d=7850)", &samples, None);

    let mlp = Mlp::new(vec![256, 64, 10]);
    let ds2 = gaussian_clusters(2000, 256, 10, 0.2, 1.0, 2);
    let batch2 = ds2.gather(&(0..16).collect::<Vec<_>>());
    params = mlp.init_params(1);
    grad = vec![0.0f32; mlp.dim()];
    let samples = time_iters(warm * 10, iters * 30, || {
        std::hint::black_box(mlp.loss_grad(&params, &batch2, &mut grad));
    });
    rec.report("grad/native-mlp(b=16,d=17k)", &samples, None);

    // PJRT grad latency (if artifacts exist and this build can run them).
    if std::path::Path::new("artifacts/manifest.json").exists() && PjrtRuntime::backend_available()
    {
        let rt = PjrtRuntime::open("artifacts").unwrap();
        let pj = rt.load_model("softmax").unwrap();
        let mut g = vec![0.0f32; pj.dim()];
        let p = vec![0.01f32; pj.dim()];
        let samples = time_iters(warm * 5, iters * 10, || {
            std::hint::black_box(pj.loss_grad(&p, &batch, &mut g));
        });
        rec.report("grad/pjrt-softmax(b=8,d=7850)", &samples, None);

        let lm = rt.load_model("lm").unwrap();
        let e = lm.entry.clone();
        let seq = e.seq.unwrap();
        let toks: Vec<f32> = (0..e.batch * (seq + 1)).map(|i| (i % 200) as f32).collect();
        let lb = qsparse::data::Batch { x: toks, y: vec![0; e.batch], b: e.batch, dim: seq + 1 };
        let lp = rt.load_init("lm").unwrap().unwrap();
        let mut lg = vec![0.0f32; lm.dim()];
        let samples = time_iters(1, if quick { 2 } else { 5 }, || {
            std::hint::black_box(lm.loss_grad(&lp, &lb, &mut lg));
        });
        rec.report("grad/pjrt-lm(b=8,d=471k)", &samples, None);
    } else {
        println!(
            "(artifacts/ or the `pjrt` feature missing — skipping PJRT benches; \
             run `make artifacts` and build with --features pjrt)"
        );
    }

    // Full engine step (R=8) vs 8× raw grad: the difference is coordination.
    // Sequential baseline first, then the parallel engine at the machine's
    // core count — bit-identical histories, so this is a pure speed knob.
    let steps = if quick { 20 } else { 100 };
    let engine_iters = if quick { 2 } else { 4 };
    // Operator/schedule construction hoisted out of the timed closure so the
    // reported per-step cost is the engine's alone.
    let comp = parse_spec("signtopk:k=170,m=1").unwrap();
    let sched = FixedPeriod::new(1);
    let run_engine = |threads: usize, steps: usize| {
        let mut spec = TrainSpec::new(&softmax, &ds, comp.as_ref(), &sched);
        spec.workers = 8;
        spec.batch = 8;
        spec.steps = steps;
        spec.lr = LrSchedule::Const { eta: 0.1 };
        spec.sharding = Sharding::Iid;
        spec.eval_every = steps + 1; // exclude eval cost
        spec.threads = threads;
        std::hint::black_box(run(&spec));
    };
    let samples = time_iters(0, engine_iters, || run_engine(1, steps));
    let per_step: Vec<f64> = samples.iter().map(|s| s / steps as f64).collect();
    let engine_step = rec.report("engine/step(R=8,signtopk,H=1)", &per_step, None);
    let overhead = (engine_step - 8.0 * native_softmax_grad) / engine_step * 100.0;
    println!(
        "\ncoordination overhead: engine step {} vs 8x raw grad {} -> {overhead:.1}% of step",
        fmt_duration(engine_step),
        fmt_duration(8.0 * native_softmax_grad),
    );

    let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
    let pool = cores.min(8);
    if pool > 1 {
        let samples = time_iters(0, engine_iters, || run_engine(pool, steps));
        let per_step: Vec<f64> = samples.iter().map(|s| s / steps as f64).collect();
        let name = format!("engine/step-par(R=8,signtopk,H=1,threads={pool})");
        let par_step = rec.report(&name, &per_step, None);
        let speedup = engine_step / par_step;
        println!("parallel engine speedup at {pool} threads ({cores} cores): {speedup:.2}x");
        rec.value(&format!("engine/speedup(R=8,threads={pool})"), speedup);
    }

    // Steady-state allocations per engine step: diff a 2N-step run against
    // an N-step run so setup/teardown and the final eval cancel exactly.
    let alloc_steps = if quick { 20 } else { 40 };
    for threads in [1usize, pool] {
        let a1 = count_allocs(|| run_engine(threads, alloc_steps));
        let a2 = count_allocs(|| run_engine(threads, 2 * alloc_steps));
        let per_step = a2.saturating_sub(a1) as f64 / alloc_steps as f64;
        rec.value(
            &format!("alloc/engine-steady-per-step(R=8,signtopk,H=1,threads={threads})"),
            per_step,
        );
        if threads == 1 {
            note_steady_alloc("signtopk", per_step);
        }
        if threads == pool {
            break;
        }
    }

    // RandK joined the zero-allocation guarantee (its distinct-index sampler
    // now draws through reusable scratch): probe it end-to-end too.
    {
        let randk = parse_spec("randk:k=170").unwrap();
        let run_randk = |steps: usize| {
            let mut spec = TrainSpec::new(&softmax, &ds, randk.as_ref(), &sched);
            spec.workers = 8;
            spec.batch = 8;
            spec.steps = steps;
            spec.lr = LrSchedule::Const { eta: 0.1 };
            spec.eval_every = steps + 1;
            std::hint::black_box(run(&spec));
        };
        let a1 = count_allocs(|| run_randk(alloc_steps));
        let a2 = count_allocs(|| run_randk(2 * alloc_steps));
        let per_step = a2.saturating_sub(a1) as f64 / alloc_steps as f64;
        rec.value("alloc/engine-steady-per-step(R=8,randk,H=1,threads=1)", per_step);
        note_steady_alloc("randk", per_step);
    }

    // Compress / encode micro path: the allocating API vs the `_into`
    // reusable-buffer API (before/after of §Perf iteration 5), plus the
    // pure wire_bits cost walk.
    bench_compress_paths(&mut rec, warm, iters, &ds, &softmax);

    // The dispatched SIMD kernels in isolation, plus auto-vs-forced-scalar
    // speed ratios `scripts/check_bench.py` gates (≤ 1.0 on multi-core
    // runners: the vectorized path must never lose to its scalar twin).
    bench_simd_kernels(&mut rec, warm, iters);

    // Broadcast path (master side, R=8, d=7850): dense model snapshot vs
    // error-compensated compressed delta per worker. Shows both the wall
    // cost of the downlink aggregation work and the wire-bit savings.
    bench_broadcast(&mut rec, quick, warm, iters);

    // Aggregation under sampled participation: full R-worker rounds vs
    // |S_t| = m sampled rounds with the unbiased 1/|S_t| fold.
    bench_participation_aggregation(&mut rec, warm, iters);

    // The master's round in isolation (fold + downlink compression),
    // sequential vs sharded across a persistent pool, over the R × threads
    // grid — the tail the parallel master round removes.
    bench_master_round(&mut rec, quick, warm, iters);

    // Threaded-coordinator steady state: the decode → fold path must be
    // allocation-free per update; the whole-run residual is channel
    // transport, recorded for the trajectory.
    bench_threaded_coordinator(&mut rec, quick);

    // The event-driven network simulator: per-step cost of the virtual-clock
    // overlay on the shared arithmetic, the scheduler micro cost, and the
    // sim loop's steady-state allocation count (zero, like the engine).
    bench_sim(&mut rec, quick, warm, iters, &ds, &softmax);

    // Fault-tolerance machinery: the stateless per-message fault decision,
    // checkpoint snapshot serialization, and the faulty sim loop's
    // steady-state allocation count (zero, like its fault-free twin).
    bench_faults(&mut rec, quick, warm, iters, &ds, &softmax);

    if json {
        rec.write_json("BENCH_train_step.json");
    }
}

/// Hard check: the sequential engine's steady state is allocation-free by
/// design (and, since the Rand_k sampler rework, for every built-in
/// operator). The probe is a deterministic allocator count — not timing —
/// so a non-zero reading is a real regression, and this bench (which CI
/// runs) fails loudly instead of warning.
fn note_steady_alloc(op: &str, per_step: f64) {
    assert!(
        per_step == 0.0,
        "sequential engine ({op}) steady state allocates {per_step:.2} times per step — \
         the zero-allocation hot path has regressed"
    );
    println!("sequential engine ({op}) steady state: {per_step:.1} allocations/step (target 0)");
}

fn bench_compress_paths(
    rec: &mut Recorder,
    warm: usize,
    iters: usize,
    ds: &Dataset,
    softmax: &SoftmaxRegression,
) {
    // A realistic input: an actual anchored gradient-scale vector.
    let d = softmax.dim();
    let batch = ds.gather(&(0..32).collect::<Vec<_>>());
    let params = vec![0.01f32; d];
    let mut x = vec![0.0f32; d];
    softmax.loss_grad(&params, &batch, &mut x);

    for spec in ["signtopk:k=170,m=1", "topk:k=400", "qtopk:k=400,bits=4", "randk:k=400"] {
        let op = parse_spec(spec).unwrap();
        let mut rng = Pcg64::seeded(3);
        let samples = time_iters(warm * 5, iters * 20, || {
            std::hint::black_box(op.compress(&x, &mut rng));
        });
        rec.report(&format!("compress/{spec}(d=7850)"), &samples, None);

        let mut rng = Pcg64::seeded(3);
        let mut buf = MessageBuf::new();
        let samples = time_iters(warm * 5, iters * 20, || {
            op.compress_into(&x, &mut rng, &mut buf);
            std::hint::black_box(buf.message().nnz());
        });
        rec.report(&format!("compress_into/{spec}(d=7850)"), &samples, None);
        let calls = 50u64;
        let mut rng = Pcg64::seeded(4);
        let allocs = count_allocs(|| {
            for _ in 0..calls {
                op.compress_into(&x, &mut rng, &mut buf);
            }
        });
        rec.value(&format!("alloc/compress_into-per-call/{spec}"), allocs as f64 / calls as f64);

        // Encode the message: allocating vs reusable writer vs pure cost walk.
        let mut rng = Pcg64::seeded(5);
        let msg = op.compress(&x, &mut rng);
        let samples = time_iters(warm * 5, iters * 20, || {
            std::hint::black_box(encode::encode(&msg));
        });
        rec.report(&format!("encode/{spec}(d=7850)"), &samples, None);
        let mut w = encode::BitWriter::new();
        let samples = time_iters(warm * 5, iters * 20, || {
            encode::encode_into(&msg, &mut w);
            std::hint::black_box(w.finish().1);
        });
        rec.report(&format!("encode_into/{spec}(d=7850)"), &samples, None);
        let samples = time_iters(warm * 5, iters * 20, || {
            std::hint::black_box(encode::wire_bits(&msg));
        });
        rec.report(&format!("wire_bits/{spec}(d=7850)"), &samples, None);

        // Decode the wire bytes back: the allocating decoder vs the
        // recycled-buffer `decode_into` (the threaded master's receive
        // path), whose steady state must not touch the heap.
        let (bytes, bit_len) = encode::encode(&msg);
        let samples = time_iters(warm * 5, iters * 20, || {
            std::hint::black_box(encode::decode(&bytes, bit_len).is_ok());
        });
        rec.report(&format!("decode/{spec}(d=7850)"), &samples, None);
        let mut dbuf = MessageBuf::new();
        let samples = time_iters(warm * 5, iters * 20, || {
            encode::decode_into(&bytes, bit_len, &mut dbuf).expect("bench message decodes");
            std::hint::black_box(dbuf.message().nnz());
        });
        rec.report(&format!("decode_into/{spec}(d=7850)"), &samples, None);
        let allocs = count_allocs(|| {
            for _ in 0..calls {
                encode::decode_into(&bytes, bit_len, &mut dbuf).expect("bench message decodes");
            }
        });
        let per_call = allocs as f64 / calls as f64;
        rec.value(&format!("alloc/decode_into-per-call/{spec}"), per_call);
        assert!(
            per_call == 0.0,
            "decode_into allocated {per_call:.2} times per call for {spec} — \
             the zero-allocation decode path has regressed"
        );

        // The rANS codec over the same message: entropy-coded encode/decode
        // latency, the pure cost walk, the steady-state allocation probes
        // (the reused `WireEncoder` scratch must make both directions heap-
        // free after warm-up), and the realized rans-vs-raw wire-bit ratio
        // (≤ 1.0 by construction — the per-message fallback keeps raw
        // whenever the entropy-coded container would not be strictly
        // smaller; `scripts/check_bench.py` gates the savings).
        let mut rwire = WireEncoder::new(Codec::Rans);
        let samples = time_iters(warm * 5, iters * 20, || {
            std::hint::black_box(rwire.encode(&msg).1);
        });
        rec.report(&format!("encode-rans/{spec}(d=7850)"), &samples, None);
        let allocs = count_allocs(|| {
            for _ in 0..calls {
                std::hint::black_box(rwire.encode(&msg).1);
            }
        });
        let per_call = allocs as f64 / calls as f64;
        rec.value(&format!("alloc/encode-rans-per-call/{spec}"), per_call);
        assert!(
            per_call == 0.0,
            "rANS encode allocated {per_call:.2} times per call for {spec} — \
             the zero-allocation encode path has regressed"
        );

        let (rbytes, rbits) = rwire.encode(&msg);
        let rbytes = rbytes.to_vec();
        assert_eq!(
            msg.wire_bits_with(Codec::Rans),
            rbits,
            "wire_bits_with(Rans) disagrees with the rANS encoder for {spec}"
        );
        let samples = time_iters(warm * 5, iters * 20, || {
            encode::decode_into(&rbytes, rbits, &mut dbuf).expect("bench rans message decodes");
            std::hint::black_box(dbuf.message().nnz());
        });
        rec.report(&format!("decode-rans/{spec}(d=7850)"), &samples, None);
        let allocs = count_allocs(|| {
            for _ in 0..calls {
                encode::decode_into(&rbytes, rbits, &mut dbuf).expect("bench rans message decodes");
            }
        });
        let per_call = allocs as f64 / calls as f64;
        rec.value(&format!("alloc/decode-rans-per-call/{spec}"), per_call);
        assert!(
            per_call == 0.0,
            "rANS decode allocated {per_call:.2} times per call for {spec} — \
             the zero-allocation decode path has regressed"
        );

        let samples = time_iters(warm * 5, iters * 20, || {
            std::hint::black_box(msg.wire_bits_with(Codec::Rans));
        });
        rec.report(&format!("wire_bits-rans/{spec}(d=7850)"), &samples, None);
        let ratio = rbits as f64 / bit_len as f64;
        rec.value(&format!("codec/rans-vs-raw-bits/{spec}(d=7850)"), ratio);
        println!("  rans wire bits for {spec}: {rbits} vs raw {bit_len} ({ratio:.3}x)");
    }

    // Skewed-gap probe: a clustered support (a dense run of indices inside a
    // large model) is the regime the gap/level entropy coder targets — the
    // γ-class symbols collapse to near-zero entropy. Deterministic input, so
    // the ratio is a hard number `scripts/check_bench.py` can gate.
    let d_big = 1usize << 20;
    let idx: Vec<u32> = (500u32..628).collect();
    let vals: Vec<f32> = idx.iter().map(|&i| 1.5 + (i % 4) as f32 * 0.25).collect();
    let skewed = qsparse::Message::SparseF32 { d: d_big, idx, vals };
    let raw_bits = skewed.wire_bits();
    let rans_bits = skewed.wire_bits_with(Codec::Rans);
    let ratio = rans_bits as f64 / raw_bits as f64;
    rec.value("codec/rans-vs-raw-bits/skewed-gaps(d=1M)", ratio);
    println!("  rans wire bits for skewed gaps: {rans_bits} vs raw {raw_bits} ({ratio:.3}x)");
}

/// Noise-robust comparator for the A/B ratios: best observed sample.
fn min_sample(samples: &[f64]) -> f64 {
    samples.iter().copied().fold(f64::INFINITY, f64::min)
}

/// The four dispatched SIMD kernels in isolation (auto backend), then the
/// same kernels pinned to the scalar twin via `force_backend` for the
/// `simd/speedup-vs-scalar/*` ratios (auto_min / scalar_min). When
/// detection already lands on scalar (no AVX2/Neon, or
/// `QSPARSE_FORCE_SCALAR=1`) the A/B would race identical code against
/// itself, so the ratios are emitted as exactly 1.0 — flake-free.
fn bench_simd_kernels(rec: &mut Recorder, warm: usize, iters: usize) {
    use qsparse::simd::{self, Backend};

    let d = 1usize << 18;
    let mut rng = Pcg64::seeded(47);
    let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();

    // Threshold with a ~1% pass rate, taken from the real packed-key
    // distribution (what `top_k_sampled_into` estimates from its sample).
    let mut packed = Vec::new();
    simd::pack_ordered_into(&x, &mut packed);
    let mut keys: Vec<u32> = packed.iter().map(|&p| (p >> 32) as u32).collect();
    keys.sort_unstable();
    let thresh = keys[d - d / 100];

    // A raw fixed-width index stream: 4096 fields of 24 bits, the coding a
    // k=4096 support in a d=16M model lands on (γ(4096) = 25 bits > 24).
    let mut irng = Pcg64::seeded(53);
    let idx_bytes: Vec<u8> = (0..4096 * 3).map(|_| irng.next_u32() as u8).collect();

    let mut cand: Vec<u64> = Vec::new();
    let mut levels: Vec<u32> = Vec::new();
    let mut neg: Vec<bool> = Vec::new();
    let mut acc = vec![0.0f32; d];
    let mut out_idx: Vec<u32> = Vec::new();
    let mut qrng = Pcg64::seeded(59);

    let auto = simd::force_backend(None);
    println!("simd backend: {}", auto.name());

    let scan = time_iters(warm * 2, iters * 10, || {
        cand.clear();
        std::hint::black_box(simd::scan_threshold_into(&x, thresh, d, &mut cand));
    });
    rec.report("simd/topk-scan(d=256k)", &scan, None);
    let qsgd = time_iters(warm * 2, iters * 10, || {
        levels.clear();
        neg.clear();
        let norm = simd::norm2_sq_chunked(&x).sqrt() as f32;
        let inv = if norm > 0.0 { 15.0 / norm } else { 0.0 };
        simd::quantize_bucket_into(&x, inv, 15, &mut qrng, &mut levels, &mut neg);
        std::hint::black_box(levels.len());
    });
    rec.report("simd/qsgd-quantize(d=256k)", &qsgd, None);
    let fold = time_iters(warm * 2, iters * 10, || {
        simd::add_scaled(&mut acc, &x, 0.125);
        std::hint::black_box(acc[0]);
    });
    rec.report("simd/fold-dense(d=256k)", &fold, None);
    let unpack = time_iters(warm * 2, iters * 10, || {
        out_idx.clear();
        simd::unpack_fixed_into(&idx_bytes, 0, 24, 4096, &mut out_idx);
        std::hint::black_box(out_idx.len());
    });
    rec.report("simd/unpack-indices(w=24,n=4096)", &unpack, None);

    if auto == Backend::Scalar {
        for k in ["topk-scan", "qsgd-quantize", "fold-dense", "unpack-indices"] {
            rec.value(&format!("simd/speedup-vs-scalar/{k}"), 1.0);
        }
        return;
    }

    simd::force_backend(Some(Backend::Scalar));
    let s_scan = time_iters(warm * 2, iters * 10, || {
        cand.clear();
        std::hint::black_box(simd::scan_threshold_into(&x, thresh, d, &mut cand));
    });
    let s_qsgd = time_iters(warm * 2, iters * 10, || {
        levels.clear();
        neg.clear();
        let norm = simd::norm2_sq_chunked(&x).sqrt() as f32;
        let inv = if norm > 0.0 { 15.0 / norm } else { 0.0 };
        simd::quantize_bucket_into(&x, inv, 15, &mut qrng, &mut levels, &mut neg);
        std::hint::black_box(levels.len());
    });
    let s_fold = time_iters(warm * 2, iters * 10, || {
        simd::add_scaled(&mut acc, &x, 0.125);
        std::hint::black_box(acc[0]);
    });
    let s_unpack = time_iters(warm * 2, iters * 10, || {
        out_idx.clear();
        simd::unpack_fixed_into(&idx_bytes, 0, 24, 4096, &mut out_idx);
        std::hint::black_box(out_idx.len());
    });
    simd::force_backend(None);

    for (k, a, s) in [
        ("topk-scan", &scan, &s_scan),
        ("qsgd-quantize", &qsgd, &s_qsgd),
        ("fold-dense", &fold, &s_fold),
        ("unpack-indices", &unpack, &s_unpack),
    ] {
        let ratio = min_sample(a) / min_sample(s);
        println!("  simd vs scalar ({k}): {:.2}x", 1.0 / ratio);
        rec.value(&format!("simd/speedup-vs-scalar/{k}"), ratio);
    }
}

fn bench_broadcast(rec: &mut Recorder, quick: bool, warm: usize, iters: usize) {
    use qsparse::protocol::MasterCore;
    use std::sync::Arc;

    let d = 7850usize;
    let workers = 8usize;
    let mut rng = Pcg64::seeded(7);
    let init: Vec<f32> = (0..d).map(|_| rng.normal_f32() * 0.1).collect();
    let drift = || -> Vec<f32> {
        let mut r = Pcg64::seeded(8);
        (0..d).map(|_| r.normal_f32() * 0.01).collect()
    };

    // Dense downlink: one cached Arc snapshot per round (what the threaded
    // master sends — rebuilt only after the model changes), bits = encoded
    // dense model per worker. The drift update is prebuilt outside the
    // timed closure so the clone does not pollute the measurement.
    let mut core = MasterCore::new(init.clone(), workers, 7, false);
    let noise_upd = qsparse::Message::Dense { values: drift() };
    let samples = time_iters(warm * 5, iters * 20, || {
        core.apply_update(&noise_upd).unwrap();
        let payload: Arc<[f32]> = core.params_snapshot();
        for _r in 0..workers {
            std::hint::black_box(Arc::clone(&payload));
        }
    });
    rec.report("broadcast/dense(R=8,d=7850)", &samples, Some(4 * d));
    let dense_bits = workers as u64 * encode::dense_model_bits(d);

    // Compressed downlink: per-worker EF delta + wire encoding, through the
    // reusable buffer + writer (the engine/coordinator hot path).
    for spec in ["topk:k=400", "qtopk:k=400,bits=4"] {
        let down = parse_spec(spec).unwrap();
        let mut core = MasterCore::new(init.clone(), workers, 7, true);
        let noise_upd = qsparse::Message::Dense { values: drift() };
        let mut buf = MessageBuf::new();
        let mut wire = encode::BitWriter::new();
        let mut round_bits = 0u64;
        let mut rounds = 0u64;
        let samples = time_iters(warm * 5, if quick { iters * 5 } else { iters * 20 }, || {
            core.apply_update(&noise_upd).unwrap();
            for r in 0..workers {
                core.delta_broadcast_into(r, down.as_ref(), &mut buf);
                encode::encode_into(buf.message(), &mut wire);
                round_bits += wire.finish().1;
            }
            rounds += 1;
        });
        rec.report(&format!("broadcast/{spec}(R=8,d=7850)"), &samples, None);
        let avg_bits = round_bits / rounds.max(1);
        println!(
            "  downlink bits/round: {avg_bits} vs dense {dense_bits} ({:.1}x saving)",
            dense_bits as f64 / avg_bits as f64
        );
    }
}

/// The master's round in isolation: fold R decoded updates into the fold
/// target, then compute/compress/account R error-compensated downlink
/// deltas — sequential (the pre-parallelization tail) vs sharded across a
/// persistent pool. The parallel harness mirrors `engine/parallel.rs`'s
/// ownership split exactly: each thread owns a disjoint contiguous chunk
/// of the fold target (folded with `Message::add_into_range`, messages in
/// worker order) and the `DownlinkWorker`s of a contiguous stripe of
/// workers; one rendezvous per round. Only the channel plumbing is bench-
/// local — the arithmetic is the library's.
fn bench_master_round(rec: &mut Recorder, quick: bool, warm: usize, iters: usize) {
    let d = 7850usize;
    let up = parse_spec("qtopk:k=400,bits=4").unwrap();
    let down = parse_spec("topk:k=400").unwrap();
    let rounds = if quick { 8 } else { 30 };
    let mut speedup_base = f64::NAN;
    for workers in [8usize, 32, 128] {
        // The round's decoded updates: realistic sparse uplink messages.
        let mut rng = Pcg64::seeded(17);
        let msgs: Vec<qsparse::Message> = (0..workers)
            .map(|_| {
                let x: Vec<f32> = (0..d).map(|_| rng.normal_f32() * 0.01).collect();
                up.compress(&x, &mut rng)
            })
            .collect();
        // Post-round model the downlink compresses against — held fixed so
        // every round's work is comparable (the EF anchors still advance).
        let global: Vec<f32> = (0..d).map(|_| rng.normal_f32() * 0.1).collect();
        let scale = -1.0 / workers as f32;
        for threads in [1usize, 2, 8] {
            let samples = if threads == 1 {
                master_round_seq(&msgs, &global, scale, down.as_ref(), rounds, warm, iters)
            } else {
                master_round_par(
                    threads,
                    &msgs,
                    &global,
                    scale,
                    down.as_ref(),
                    rounds,
                    warm,
                    iters,
                )
            };
            let per_round: Vec<f64> = samples.iter().map(|s| s / rounds as f64).collect();
            let mean = rec.report(
                &format!("master/round(R={workers},d=7850,down=topk400,threads={threads})"),
                &per_round,
                None,
            );
            if workers == 32 && threads == 1 {
                speedup_base = mean;
            }
            if workers == 32 && threads == 8 {
                let speedup = speedup_base / mean;
                println!("master round speedup at R=32, 8 threads: {speedup:.2}x");
                rec.value("master/round-speedup(R=32,threads=8)", speedup);
            }
        }
    }
}

/// One sequential master round ×`rounds` per timed iteration.
fn master_round_seq(
    msgs: &[qsparse::Message],
    global: &[f32],
    scale: f32,
    down: &dyn Compressor,
    rounds: usize,
    warm: usize,
    iters: usize,
) -> Vec<f64> {
    use qsparse::protocol::DownlinkWorker;
    let d = global.len();
    let mut target = vec![0.0f32; d];
    let mut downs: Vec<DownlinkWorker> = (0..msgs.len())
        .map(|r| DownlinkWorker::new(vec![0.0f32; d], 23, r))
        .collect();
    let mut scratch = vec![0.0f32; d];
    let mut buf = MessageBuf::new();
    let mut bits = 0u64;
    time_iters(warm, iters, || {
        for _ in 0..rounds {
            for m in msgs {
                m.add_into(&mut target, scale);
            }
            for dw in downs.iter_mut() {
                dw.delta_into(global, &mut scratch, down, &mut buf);
                bits += buf.message().wire_bits();
            }
        }
        std::hint::black_box(bits);
    })
}

/// As `master_round_seq`, sharded over a persistent pool of `threads`.
#[allow(clippy::too_many_arguments)]
fn master_round_par(
    threads: usize,
    msgs: &[qsparse::Message],
    global: &[f32],
    scale: f32,
    down: &dyn Compressor,
    rounds: usize,
    warm: usize,
    iters: usize,
) -> Vec<f64> {
    use qsparse::protocol::DownlinkWorker;
    use std::sync::mpsc;
    let d = global.len();
    let workers = msgs.len();
    std::thread::scope(|s| {
        let mut go_txs = Vec::with_capacity(threads);
        let mut done_rxs = Vec::with_capacity(threads);
        for ti in 0..threads {
            let (lo, hi) = (ti * d / threads, (ti + 1) * d / threads);
            let (wlo, whi) = (ti * workers / threads, (ti + 1) * workers / threads);
            let (go_tx, go_rx) = mpsc::channel::<()>();
            let (done_tx, done_rx) = mpsc::channel::<u64>();
            go_txs.push(go_tx);
            done_rxs.push(done_rx);
            s.spawn(move || {
                // Thread-owned shards, as in engine/parallel.rs: a chunk of
                // the fold target plus a stripe of downlink states.
                let mut chunk = vec![0.0f32; hi - lo];
                let mut downs: Vec<DownlinkWorker> = (wlo..whi)
                    .map(|r| DownlinkWorker::new(vec![0.0f32; d], 23, r))
                    .collect();
                let mut scratch = vec![0.0f32; d];
                let mut buf = MessageBuf::new();
                while go_rx.recv().is_ok() {
                    let mut bits = 0u64;
                    for m in msgs {
                        m.add_into_range(&mut chunk, scale, lo..hi);
                    }
                    for dw in downs.iter_mut() {
                        dw.delta_into(global, &mut scratch, down, &mut buf);
                        bits += buf.message().wire_bits();
                    }
                    if done_tx.send(bits).is_err() {
                        return;
                    }
                }
            });
        }
        let samples = time_iters(warm, iters, || {
            for _ in 0..rounds {
                for tx in &go_txs {
                    tx.send(()).expect("master-round pool thread died");
                }
                for rx in &done_rxs {
                    std::hint::black_box(rx.recv().expect("master-round pool thread died"));
                }
            }
        });
        drop(go_txs);
        samples
    })
}

/// Threaded-coordinator steady state. (a) The master's decode → fold path
/// — `decode_into` through per-worker recycled buffers plus the
/// incremental `apply_update` fold — must be allocation-free per update;
/// asserted. (b) The whole `run_threaded` loop's steady allocations per
/// step, recorded (not asserted): the residual is mpsc transport — one
/// node per message — which is the threaded runtime's design cost.
fn bench_threaded_coordinator(rec: &mut Recorder, quick: bool) {
    use qsparse::coordinator::{run_threaded, CoordinatorConfig};
    use qsparse::protocol::MasterCore;
    use std::sync::Arc;

    // (a) decode + fold per update, isolated from transport.
    let d = 7850usize;
    let workers = 8usize;
    let op = parse_spec("qtopk:k=400,bits=4").unwrap();
    let mut rng = Pcg64::seeded(41);
    let encoded: Vec<(Vec<u8>, u64)> = (0..workers)
        .map(|_| {
            let x: Vec<f32> = (0..d).map(|_| rng.normal_f32() * 0.01).collect();
            encode::encode(&op.compress(&x, &mut rng))
        })
        .collect();
    let mut core = MasterCore::new(vec![0.0f32; d], workers, 11, false);
    let mut bufs: Vec<MessageBuf> = (0..workers).map(|_| MessageBuf::new()).collect();
    let mut fold_round = |core: &mut MasterCore, bufs: &mut [MessageBuf]| {
        core.begin_round(workers);
        for (r, (bytes, bit_len)) in encoded.iter().enumerate() {
            encode::decode_into(bytes, *bit_len, &mut bufs[r]).expect("bench update decodes");
            core.apply_update(bufs[r].message()).unwrap();
        }
        core.end_round();
    };
    fold_round(&mut core, &mut bufs); // warm the recycled buffers
    let rounds = 50u64;
    let allocs = count_allocs(|| {
        for _ in 0..rounds {
            fold_round(&mut core, &mut bufs);
        }
    });
    let per_update = allocs as f64 / (rounds * workers as u64) as f64;
    rec.value("alloc/threaded-decode-fold-per-update(R=8,qtopk)", per_update);
    assert!(
        per_update == 0.0,
        "threaded master decode+fold allocated {per_update:.3} times per update — \
         the zero-allocation receive path has regressed"
    );
    println!("threaded master decode+fold steady state: {per_update:.1} allocations/update");

    // (b) whole threaded run (R=4, topk uplink, H=2), 2N-vs-N diff.
    let train = Arc::new(gaussian_clusters(1000, 784, 10, 0.2, 1.0, 3));
    let comp: Arc<dyn Compressor> = Arc::from(parse_spec("topk:k=170").unwrap());
    let factory = || Box::new(SoftmaxRegression::new(784, 10, 1e-4)) as Box<dyn GradModel>;
    let steps = if quick { 24 } else { 60 };
    let run_thr = |steps: usize| {
        let mut cfg = CoordinatorConfig::new(
            Arc::clone(&comp),
            Arc::new(qsparse::topology::FixedPeriod::new(2)),
        );
        cfg.workers = 4;
        cfg.batch = 8;
        cfg.steps = steps;
        cfg.lr = LrSchedule::Const { eta: 0.1 };
        cfg.eval_every = steps + 1; // exclude the eval grid
        cfg.eval_rows = 64;
        let h = run_threaded(&cfg, factory, Arc::clone(&train), None).unwrap();
        std::hint::black_box(h.final_loss());
    };
    let a1 = count_allocs(|| run_thr(steps));
    let a2 = count_allocs(|| run_thr(2 * steps));
    let per_step = a2.saturating_sub(a1) as f64 / steps as f64;
    rec.value("threaded/steady-allocs-per-step(R=4,topk,H=2)", per_step);
    println!("threaded coordinator steady state: {per_step:.1} allocations/step (channel transport)");
}

/// Master-side aggregation with sampled participation (the `begin_round` +
/// per-round scale path): full R-worker rounds vs |S_t| = m sampled rounds.
fn bench_participation_aggregation(rec: &mut Recorder, warm: usize, iters: usize) {
    use qsparse::protocol::{AggScale, MasterCore};
    use qsparse::topology::ParticipationSpec;

    let d = 7850usize;
    let workers = 8usize;
    let rounds_per_iter = 50usize;
    let mut rng = Pcg64::seeded(13);
    // Prebuilt dense update messages — no clone inside the timed closure.
    let updates: Vec<qsparse::Message> = (0..workers)
        .map(|_| qsparse::Message::Dense {
            values: (0..d).map(|_| rng.normal_f32() * 0.01).collect(),
        })
        .collect();

    for (label, spec, scale) in [
        ("full(R=8,1/R)", ParticipationSpec::Full, AggScale::Workers),
        ("fixed(m=2,1/|S|)", ParticipationSpec::FixedSize { m: 2 }, AggScale::Participants),
    ] {
        let part = spec.materialize(workers, rounds_per_iter, 29);
        let mut core = MasterCore::new(vec![0.0f32; d], workers, 29, false);
        core.set_agg_scale(scale);
        let mut s_t: Vec<usize> = Vec::with_capacity(workers);
        let samples = time_iters(warm, iters * 4, || {
            for t in 0..rounds_per_iter {
                s_t.clear();
                s_t.extend((0..workers).filter(|&r| part.participates(r, t)));
                core.begin_round(s_t.len());
                for &r in &s_t {
                    core.apply_update(&updates[r]).unwrap();
                }
            }
            std::hint::black_box(core.params().len());
        });
        let per_round: Vec<f64> =
            samples.iter().map(|s| s / rounds_per_iter as f64).collect();
        rec.report(&format!("aggregate/{label}(d=7850)"), &per_round, None);
    }
}

/// The network simulator in the loop. `sim/step` runs a fully skewed
/// scenario (speed skew, slow links, stragglers) so the probe covers queue
/// churn and transfer bookkeeping, not just the shared arithmetic; the
/// event-queue micro probe isolates the scheduler; the alloc probe diffs a
/// 2N-step sim run against an N-step run under a *compressed* downlink (a
/// dense downlink legitimately allocates one shared model snapshot per
/// round) and, like the sequential engine, must read exactly zero.
fn bench_sim(
    rec: &mut Recorder,
    quick: bool,
    warm: usize,
    iters: usize,
    ds: &Dataset,
    softmax: &SoftmaxRegression,
) {
    let comp = parse_spec("signtopk:k=170,m=1").unwrap();
    let down = parse_spec("topk:k=400").unwrap();
    let sched = FixedPeriod::new(4);
    let run_sim = |steps: usize, scen: &SimSpec| {
        let mut spec = TrainSpec::new(softmax, ds, comp.as_ref(), &sched);
        spec.workers = 8;
        spec.batch = 8;
        spec.steps = steps;
        spec.lr = LrSchedule::Const { eta: 0.1 };
        spec.sharding = Sharding::Iid;
        spec.down_compressor = down.as_ref();
        spec.eval_every = steps + 1; // exclude eval cost
        std::hint::black_box(sim::run(&spec, scen));
    };

    let skew = SimSpec {
        compute_sigma: 0.8,
        bw_sigma: 0.5,
        latency: 1_000,
        straggler_prob: 0.05,
        straggler_mult: 8.0,
        ..SimSpec::default()
    };
    let steps = if quick { 20 } else { 60 };
    let samples = time_iters(0, if quick { 2 } else { 4 }, || run_sim(steps, &skew));
    let per_step: Vec<f64> = samples.iter().map(|s| s / steps as f64).collect();
    rec.report("sim/step(R=8,signtopk,H=4,skew)", &per_step, None);

    // Scheduler micro: push 64 mixed-tick events and drain; reported per
    // push+pop pair. Capacity is pre-sized and retained across iterations.
    let mut q: EventQueue<u32> = EventQueue::with_capacity(64);
    let samples = time_iters(warm * 20, iters * 50, || {
        for i in 0..64u64 {
            q.push((i * 7919) % 97, i as u32);
        }
        while let Some(ev) = q.pop() {
            std::hint::black_box(ev);
        }
    });
    let per_op: Vec<f64> = samples.iter().map(|s| s / 64.0).collect();
    rec.report("sim/event-queue-push-pop(n=64)", &per_op, None);

    // Steady-state allocations per simulated step. Homogeneous timing (the
    // default scenario) so the count cannot depend on sampled durations;
    // same 2N-vs-N cancellation as the engine probe.
    let alloc_steps = if quick { 20 } else { 40 };
    let a1 = count_allocs(|| run_sim(alloc_steps, &SimSpec::default()));
    let a2 = count_allocs(|| run_sim(2 * alloc_steps, &SimSpec::default()));
    let per_step = a2.saturating_sub(a1) as f64 / alloc_steps as f64;
    rec.value("alloc/sim-steady-per-step(R=8,signtopk,H=4,down=topk)", per_step);
    assert!(
        per_step == 0.0,
        "sim event loop steady state allocates {per_step:.2} times per step — \
         the zero-allocation hot path has regressed"
    );
    println!("sim event loop steady state: {per_step:.1} allocations/step (target 0)");
}

/// Fault-tolerance machinery. (a) The stateless fault decision — a fresh
/// PCG keyed off (seed, worker, step, channel), one f64 draw against the
/// cumulative thresholds — is the overhead every wire hop pays under an
/// active fault spec; reported per decision. (b) A full sequential-engine
/// checkpoint snapshot (`protocol::checkpoint::save`: model, per-worker
/// cores, downlink mirrors, metric history, RNG streams) at the fig-scale
/// shape R=8, d=7850. (c) The faulty simulator's steady-state allocation
/// count: drops, delays, downlink losses and crash-restarts all ride the
/// recycled message/round buffers, so the 2N-vs-N diff must read exactly
/// zero, like the fault-free sim probe. The mix deliberately omits
/// duplication: a dup adds a second queue entry, so its seed-dependent
/// peak occupancy could cross a regrow boundary only in the 2N run's
/// second half; every other fault replaces an event one-for-one, keeping
/// the queue's high-water mark step-count-invariant.
fn bench_faults(
    rec: &mut Recorder,
    quick: bool,
    warm: usize,
    iters: usize,
    ds: &Dataset,
    softmax: &SoftmaxRegression,
) {
    use qsparse::engine::MetricPoint;
    use qsparse::faults::{Channel, FaultPlan, FaultSpec};
    use qsparse::protocol::{checkpoint, MasterCore, WorkerCore};

    // (a) decision cost under the full cocktail (every stream active).
    let cocktail = FaultSpec::parse(
        "drop=0.1,corrupt=0.05,dup=0.05,delay=0.05:20000,drop-down=0.05,\
         corrupt-down=0.05,crash=0.01,deadline=40000,seed=9",
    )
    .unwrap();
    let plan = FaultPlan::new(cocktail).expect("cocktail spec is active");
    let mut step = 0usize;
    let decisions_per_iter = 8 * 3; // 8 workers × (up, down, crash)
    let samples = time_iters(warm * 10, iters * 50, || {
        for w in 0..8usize {
            std::hint::black_box(plan.decide(w, step, Channel::Up));
            std::hint::black_box(plan.decide(w, step, Channel::Down));
            std::hint::black_box(plan.crash_at(w, step));
        }
        step += 1;
    });
    let per_msg: Vec<f64> = samples.iter().map(|s| s / decisions_per_iter as f64).collect();
    rec.report("faults/inject-per-msg", &per_msg, None);

    // (b) snapshot serialization at the standard figure shape: R=8 worker
    // cores with momentum velocity, a delta-downlink master (per-worker
    // mirrors), and a populated eval history.
    let d = softmax.dim();
    let workers_n = 8usize;
    let mut rng = Pcg64::seeded(61);
    let init: Vec<f32> = (0..d).map(|_| rng.normal_f32() * 0.1).collect();
    let master = MasterCore::new(init.clone(), workers_n, 7, true);
    let shard: Vec<usize> = (0..250).collect();
    let cores: Vec<WorkerCore> = (0..workers_n)
        .map(|r| WorkerCore::new(r, init.clone(), shard.clone(), 8, 0.9, 7))
        .collect();
    let mut history = qsparse::engine::History::new();
    for s in 0..20usize {
        history.push(MetricPoint {
            step: s * 25,
            train_loss: 1.0 / (s + 1) as f64,
            test_err: 0.5,
            test_top5_err: 0.1,
            bits_up: (s as u64) << 20,
            bits_down: (s as u64) << 22,
            mem_norm_sq: 0.25,
        });
    }
    let fp = checkpoint::spec_fingerprint("bench-checkpoint-spec");
    let size = checkpoint::save(fp, 500, 1 << 30, 1 << 32, &history, &master, &cores).len();
    let samples = time_iters(warm * 2, iters * 10, || {
        std::hint::black_box(
            checkpoint::save(fp, 500, 1 << 30, 1 << 32, &history, &master, &cores).len(),
        );
    });
    rec.report("checkpoint/snapshot(R=8,d=7850)", &samples, Some(size));

    // (c) steady-state allocations per simulated step under an active
    // fault plan. Homogeneous timing, compressed downlink, same 2N-vs-N
    // cancellation as the fault-free probe.
    let comp = parse_spec("signtopk:k=170,m=1").unwrap();
    let down = parse_spec("topk:k=400").unwrap();
    let sched = FixedPeriod::new(4);
    let faults = FaultSpec::parse(
        "drop=0.2,delay=0.1:15000,drop-down=0.1,corrupt-down=0.05,crash=0.02,\
         deadline=60000,seed=3",
    )
    .unwrap();
    let run_faulty = |steps: usize| {
        let mut spec = TrainSpec::new(softmax, ds, comp.as_ref(), &sched);
        spec.workers = 8;
        spec.batch = 8;
        spec.steps = steps;
        spec.lr = LrSchedule::Const { eta: 0.1 };
        spec.sharding = Sharding::Iid;
        spec.down_compressor = down.as_ref();
        spec.eval_every = steps + 1; // exclude eval cost
        std::hint::black_box(sim::run_from_faulty(
            &spec,
            &SimSpec::default(),
            Some(&faults),
            vec![0.0f32; softmax.dim()],
        ));
    };
    let alloc_steps = if quick { 20 } else { 40 };
    let a1 = count_allocs(|| run_faulty(alloc_steps));
    let a2 = count_allocs(|| run_faulty(2 * alloc_steps));
    let per_step = a2.saturating_sub(a1) as f64 / alloc_steps as f64;
    rec.value("alloc/fault-steady-per-step(R=8,signtopk,H=4,down=topk)", per_step);
    assert!(
        per_step == 0.0,
        "faulty sim loop steady state allocates {per_step:.2} times per step — \
         the zero-allocation fault path has regressed"
    );
    println!("faulty sim loop steady state: {per_step:.1} allocations/step (target 0)");
}
