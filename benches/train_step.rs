//! End-to-end step latency: native vs PJRT backends, and the coordinator
//! overhead on top of raw gradient compute (DESIGN.md §Perf L3 target:
//! coordination ≤ 10% of step time).

use qsparse::compress::parse_spec;
use qsparse::data::{gaussian_clusters, Sharding};
use qsparse::engine::{run, TrainSpec};
use qsparse::grad::{GradModel, Mlp, SoftmaxRegression};
use qsparse::optim::LrSchedule;
use qsparse::runtime::PjrtRuntime;
use qsparse::topology::FixedPeriod;
use qsparse::util::stats::{report, time_iters};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (warm, iters) = if quick { (1, 3) } else { (2, 10) };

    // Raw gradient latency — the floor the coordinator adds to.
    let ds = gaussian_clusters(2000, 784, 10, 0.2, 1.0, 1);
    let softmax = SoftmaxRegression::new(784, 10, 1e-4);
    let batch = ds.gather(&(0..8).collect::<Vec<_>>());
    let mut params = vec![0.01f32; softmax.dim()];
    let mut grad = vec![0.0f32; softmax.dim()];
    let samples = time_iters(warm * 20, iters * 50, || {
        std::hint::black_box(softmax.loss_grad(&params, &batch, &mut grad));
    });
    report("grad/native-softmax(b=8,d=7850)", &samples, None);
    let native_softmax_grad = qsparse::util::stats::Summary::of(&samples).mean;

    let mlp = Mlp::new(vec![256, 64, 10]);
    let ds2 = gaussian_clusters(2000, 256, 10, 0.2, 1.0, 2);
    let batch2 = ds2.gather(&(0..16).collect::<Vec<_>>());
    params = mlp.init_params(1);
    grad = vec![0.0f32; mlp.dim()];
    let samples = time_iters(warm * 10, iters * 30, || {
        std::hint::black_box(mlp.loss_grad(&params, &batch2, &mut grad));
    });
    report("grad/native-mlp(b=16,d=17k)", &samples, None);

    // PJRT grad latency (if artifacts exist and this build can run them).
    if std::path::Path::new("artifacts/manifest.json").exists() && PjrtRuntime::backend_available()
    {
        let rt = PjrtRuntime::open("artifacts").unwrap();
        let pj = rt.load_model("softmax").unwrap();
        let mut g = vec![0.0f32; pj.dim()];
        let p = vec![0.01f32; pj.dim()];
        let samples = time_iters(warm * 5, iters * 10, || {
            std::hint::black_box(pj.loss_grad(&p, &batch, &mut g));
        });
        report("grad/pjrt-softmax(b=8,d=7850)", &samples, None);

        let lm = rt.load_model("lm").unwrap();
        let e = lm.entry.clone();
        let seq = e.seq.unwrap();
        let toks: Vec<f32> = (0..e.batch * (seq + 1)).map(|i| (i % 200) as f32).collect();
        let lb = qsparse::data::Batch { x: toks, y: vec![0; e.batch], b: e.batch, dim: seq + 1 };
        let lp = rt.load_init("lm").unwrap().unwrap();
        let mut lg = vec![0.0f32; lm.dim()];
        let samples = time_iters(1, if quick { 2 } else { 5 }, || {
            std::hint::black_box(lm.loss_grad(&lp, &lb, &mut lg));
        });
        report("grad/pjrt-lm(b=8,d=471k)", &samples, None);
    } else {
        println!(
            "(artifacts/ or the `pjrt` feature missing — skipping PJRT benches; \
             run `make artifacts` and build with --features pjrt)"
        );
    }

    // Full engine step (R=8) vs 8× raw grad: the difference is coordination.
    let comp = parse_spec("signtopk:k=170,m=1").unwrap();
    let sched = FixedPeriod::new(1);
    let steps = if quick { 20 } else { 100 };
    let samples = time_iters(0, if quick { 2 } else { 4 }, || {
        let mut spec = TrainSpec::new(&softmax, &ds, comp.as_ref(), &sched);
        spec.workers = 8;
        spec.batch = 8;
        spec.steps = steps;
        spec.lr = LrSchedule::Const { eta: 0.1 };
        spec.sharding = Sharding::Iid;
        spec.eval_every = steps + 1; // exclude eval cost
        std::hint::black_box(run(&spec));
    });
    let per_step: Vec<f64> = samples.iter().map(|s| s / steps as f64).collect();
    report("engine/step(R=8,signtopk,H=1)", &per_step, None);
    let engine_step = qsparse::util::stats::Summary::of(&per_step).mean;
    let overhead = (engine_step - 8.0 * native_softmax_grad) / engine_step * 100.0;
    println!(
        "\ncoordination overhead: engine step {} vs 8x raw grad {} -> {overhead:.1}% of step",
        qsparse::util::stats::fmt_duration(engine_step),
        qsparse::util::stats::fmt_duration(8.0 * native_softmax_grad),
    );

    // Broadcast path (master side, R=8, d=7850): dense model snapshot vs
    // error-compensated compressed delta per worker. Shows both the wall
    // cost of the downlink aggregation work and the wire-bit savings.
    bench_broadcast(quick, warm, iters);

    // Aggregation under sampled participation: full R-worker rounds vs
    // |S_t| = m sampled rounds with the unbiased 1/|S_t| fold.
    bench_participation_aggregation(warm, iters);
}

fn bench_broadcast(quick: bool, warm: usize, iters: usize) {
    use qsparse::compress::encode;
    use qsparse::protocol::MasterCore;
    use qsparse::util::rng::Pcg64;
    use std::sync::Arc;

    let d = 7850usize;
    let workers = 8usize;
    let mut rng = Pcg64::seeded(7);
    let init: Vec<f32> = (0..d).map(|_| rng.normal_f32() * 0.1).collect();
    let drift = || -> Vec<f32> {
        let mut r = Pcg64::seeded(8);
        (0..d).map(|_| r.normal_f32() * 0.01).collect()
    };

    // Dense downlink: one cached Arc snapshot per round (what the threaded
    // master sends — rebuilt only after the model changes), bits = encoded
    // dense model per worker.
    let mut core = MasterCore::new(init.clone(), workers, 7, false);
    let noise = drift();
    let samples = time_iters(warm * 5, iters * 20, || {
        core.apply_update(&qsparse::Message::Dense { values: noise.clone() }).unwrap();
        let payload: Arc<[f32]> = core.params_snapshot();
        for _r in 0..workers {
            std::hint::black_box(Arc::clone(&payload));
        }
    });
    report("broadcast/dense(R=8,d=7850)", &samples, Some(4 * d));
    let dense_bits = workers as u64 * encode::dense_model_bits(d);

    // Compressed downlink: per-worker EF delta + wire encoding.
    for spec in ["topk:k=400", "qtopk:k=400,bits=4"] {
        let down = parse_spec(spec).unwrap();
        let mut core = MasterCore::new(init.clone(), workers, 7, true);
        let noise = drift();
        let mut round_bits = 0u64;
        let mut rounds = 0u64;
        let samples = time_iters(warm * 5, if quick { iters * 5 } else { iters * 20 }, || {
            core.apply_update(&qsparse::Message::Dense { values: noise.clone() }).unwrap();
            for r in 0..workers {
                let msg = core.delta_broadcast(r, down.as_ref());
                let (bytes, bit_len) = encode::encode(&msg);
                round_bits += bit_len;
                std::hint::black_box(bytes);
            }
            rounds += 1;
        });
        report(&format!("broadcast/{spec}(R=8,d=7850)"), &samples, None);
        let avg_bits = round_bits / rounds.max(1);
        println!(
            "  downlink bits/round: {avg_bits} vs dense {dense_bits} ({:.1}x saving)",
            dense_bits as f64 / avg_bits as f64
        );
    }
}

/// Master-side aggregation with sampled participation (the `begin_round` +
/// per-round scale path): full R-worker rounds vs |S_t| = m sampled rounds.
fn bench_participation_aggregation(warm: usize, iters: usize) {
    use qsparse::protocol::{AggScale, MasterCore};
    use qsparse::topology::ParticipationSpec;
    use qsparse::util::rng::Pcg64;

    let d = 7850usize;
    let workers = 8usize;
    let rounds_per_iter = 50usize;
    let mut rng = Pcg64::seeded(13);
    let updates: Vec<Vec<f32>> = (0..workers)
        .map(|_| (0..d).map(|_| rng.normal_f32() * 0.01).collect())
        .collect();

    for (label, spec, scale) in [
        ("full(R=8,1/R)", ParticipationSpec::Full, AggScale::Workers),
        ("fixed(m=2,1/|S|)", ParticipationSpec::FixedSize { m: 2 }, AggScale::Participants),
    ] {
        let part = spec.materialize(workers, rounds_per_iter, 29);
        let mut core = MasterCore::new(vec![0.0f32; d], workers, 29, false);
        core.set_agg_scale(scale);
        let samples = time_iters(warm, iters * 4, || {
            for t in 0..rounds_per_iter {
                let s_t: Vec<usize> =
                    (0..workers).filter(|&r| part.participates(r, t)).collect();
                core.begin_round(s_t.len());
                for r in s_t {
                    core.apply_update(&qsparse::Message::Dense {
                        values: updates[r].clone(),
                    })
                    .unwrap();
                }
            }
            std::hint::black_box(core.params().len());
        });
        let per_round: Vec<f64> =
            samples.iter().map(|s| s / rounds_per_iter as f64).collect();
        report(&format!("aggregate/{label}(d=7850)"), &per_round, None);
    }
}
